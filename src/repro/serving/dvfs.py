"""Sentence-level latency-aware DVFS (paper Alg. 1, §IV; system Fig. 9).

EdgeBERT's headline mechanism: entropy-based early-exit *prediction* drives
dynamic voltage-frequency scaling per sentence, so each inference finishes
"just in time" at the lowest energy instead of racing to idle at max clock.

Mapping to the paper:

  * **Alg. 1 line 1** (run the first encoder layer at nominal VDD/freq):
    ``sentence_report`` always charges layer 1 at the table's top operating
    point — the LDO/ADPLL switch only after the first off-ramp is evaluated.
  * **Alg. 1 line 2** (predict the exit layer from the first off-ramp's
    entropy): ``core.early_exit.ExitPredictor``, a binned LUT calibrated
    offline (``calibrate_predictor``) — the ASIC's small SRAM table.
  * **Alg. 1 lines 3-4** (pick the minimum (V, f) that finishes the predicted
    remaining layers within the latency target): ``select_op`` scans the
    ``DVFS table`` (fast-switching LDO + ADPLL operating points, Fig. 9's
    clock/power management blocks) for the slowest point whose frequency
    still meets ``remaining_cycles / remaining_time``.
  * **Misprediction guard**: if the sentence has not exited by its predicted
    layer, remaining layers escalate to the maximum operating point so the
    latency target stays bounded (the paper's latency-aware guarantee).
  * **Energy accounting**: per-layer energy comes from the calibrated
    accelerator model (``hwmodel.edgebert_accel``); dynamic energy scales as
    (VDD/VDD_NOM)^2 and latency as cycles/f, so the DVFS win is quadratic in
    the voltage headroom the early-exit prediction uncovers.

The controller is deliberately analytic + host-side: the serving engine
(`serving/engine.py`) records each sentence's off-ramp entropy trace while
the fixed-shape batched step runs, and the controller replays Alg. 1 over
that trace to produce the per-sentence (V, f) schedule and energy/latency
report.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.early_exit import (
    ExitPredictor,
    OnlineExitCalibrator,
    fit_exit_predictor,
    predict_exit_layer,
)
from repro.hwmodel.edgebert_accel import (
    CLOCK_HZ,
    VDD_NOM,
    WorkloadStats,
    accel_power_mw,
    albert_layer_stats,
    layer_cycles,
    layer_energy_j,
    op_switch_overhead,
    scale_stats_to_seq_len,
)


@dataclass(frozen=True)
class OperatingPoint:
    """One LDO/ADPLL setting: supply voltage (V) and clock frequency (Hz)."""

    vdd: float
    freq_hz: float


# Fast-switching LDO (25mV steps) + ADPLL operating points for the 12nm
# design; the top entry is the nominal point the TableV anchors are fitted
# at.  Voltage ascends with frequency, so per-cycle energy is monotone in
# the table index — the property the controller's energy guarantees rest on.
DEFAULT_DVFS_TABLE: Tuple[OperatingPoint, ...] = (
    OperatingPoint(0.50, 100e6),
    OperatingPoint(0.55, 166e6),
    OperatingPoint(0.60, 250e6),
    OperatingPoint(0.65, 333e6),
    OperatingPoint(0.70, 400e6),
    OperatingPoint(VDD_NOM, CLOCK_HZ),
)


@dataclass
class DVFSReport:
    """Per-sentence outcome of Alg. 1."""

    exit_layer: int
    predicted_exit: float
    op: OperatingPoint              # point selected after the first off-ramp
    latency_s: float
    energy_j: float
    deadline_met: bool
    energy_max_freq_j: float        # same exit schedule, always at max V/f
    escalated_layers: int           # layers run at max point after a mispredict


def no_early_exit_baseline(
    stats: WorkloadStats,
    *,
    n: int = 16,
    op: OperatingPoint = DEFAULT_DVFS_TABLE[-1],
    use_span: bool = True,
    use_sparsity: bool = True,
) -> Dict[str, float]:
    """Conventional inference: all ``stats.n_layers`` layers at ``op``.

    Standalone so callers can derive a latency target BEFORE constructing the
    controller (the usual idiom: target = the full-model latency).
    """
    cyc = layer_cycles(stats, n, use_span=use_span)
    e = layer_energy_j(stats, n, vdd=op.vdd, use_span=use_span, use_sparsity=use_sparsity)
    L = stats.n_layers
    return {"latency_s": L * cyc / op.freq_hz, "energy_j": L * e}


class LatencyAwareDVFSController:
    """Replays paper Alg. 1 over a sentence's off-ramp entropy trace.

    Parameters
    ----------
    stats:            workload statistics of ONE encoder layer pass (from the
                      JAX model or ``albert_layer_stats``).
    target_latency_s: the prescribed per-sentence latency target T.
    predictor:        entropy -> exit-layer LUT; ``None`` predicts the full
                      ``stats.n_layers`` (conservative: never misses deadline,
                      saves least energy).
    """

    def __init__(
        self,
        stats: WorkloadStats,
        target_latency_s: float,
        *,
        table: Sequence[OperatingPoint] = DEFAULT_DVFS_TABLE,
        n: int = 16,
        predictor: Optional[ExitPredictor] = None,
        online_calibrator: Optional[OnlineExitCalibrator] = None,
        use_span: bool = True,
        use_sparsity: bool = True,
    ):
        assert target_latency_s > 0
        table = tuple(sorted(table, key=lambda p: p.freq_hz))
        assert all(
            a.vdd <= b.vdd for a, b in zip(table, table[1:])
        ), "DVFS table voltage must ascend with frequency"
        self.stats = stats
        self.target_latency_s = float(target_latency_s)
        self.table = table
        self.n = n
        self.predictor = predictor
        # online mode: the LUT is a set of RUNNING per-bin quantiles updated
        # by observe_exit() as sentences retire (no offline profiling pass);
        # takes precedence over a static ``predictor`` once armed
        self.online = online_calibrator
        self._use_span = use_span
        self.cycles_per_layer = layer_cycles(stats, n, use_span=use_span)
        self._bucket_cycles: Dict[int, float] = {int(stats.seq_len): self.cycles_per_layer}
        # per-layer energy at each table point: E ~ (V/V_nom)^2, f-independent
        self._e_layer = {
            op: layer_energy_j(
                stats, n, vdd=op.vdd, use_span=use_span, use_sparsity=use_sparsity
            )
            for op in table
        }

    # ----------------------------------------------------------- primitives
    @property
    def max_op(self) -> OperatingPoint:
        return self.table[-1]

    def layer_time_s(self, op: OperatingPoint) -> float:
        return self.cycles_per_layer / op.freq_hz

    def layer_energy(self, op: OperatingPoint) -> float:
        return self._e_layer[op]

    def cycles_for_seq_len(self, seq_len: int) -> float:
        """Per-bucket cycle model: layer cycles at ``seq_len``, from the
        controller's stats rescaled token-linearly (matmul/vector) and
        token-quadratically (attention scores).  Cached per length — this is
        what lets the batched arbiter budget each lane at ITS bucket's cost
        instead of the largest bucket's (ROADMAP per-bucket-cycles item)."""
        key = int(seq_len)
        if key not in self._bucket_cycles:
            self._bucket_cycles[key] = layer_cycles(
                scale_stats_to_seq_len(self.stats, key), self.n,
                use_span=self._use_span,
            )
        return self._bucket_cycles[key]

    def op_for_freq(self, need_hz: float) -> OperatingPoint:
        """Slowest table point with freq >= need_hz (max point if none) —
        the single op-selection rule shared by per-sentence Alg. 1 and the
        batched arbiter, so the two cannot drift apart."""
        for op in self.table:
            if op.freq_hz >= need_hz:
                return op
        return self.max_op

    def select_op(self, predicted_remaining: float, remaining_time_s: float) -> OperatingPoint:
        """Alg. 1 lines 3-4: slowest point meeting the remaining budget."""
        if remaining_time_s <= 0:
            return self.max_op
        need_hz = max(predicted_remaining, 0.0) * self.cycles_per_layer / remaining_time_s
        return self.op_for_freq(need_hz)

    def predict(self, first_entropy: float) -> float:
        if self.online is not None:
            p = self.online.predict(first_entropy)
        elif self.predictor is not None:
            p = predict_exit_layer(self.predictor, first_entropy)
        else:
            return float(self.stats.n_layers)
        return float(np.clip(p, 1.0, self.stats.n_layers))

    def observe_exit(self, first_entropy: float, exit_layer: int) -> None:
        """Online calibration: fold a retired sentence's (first entropy, exit
        layer) into the running per-bin quantiles — the LUT adapts DURING a
        drain instead of needing the offline ``calibrate_predictor`` pass."""
        if self.online is not None:
            self.online.observe(first_entropy, exit_layer)

    # -------------------------------------------------------------- Alg. 1
    def sentence_report(
        self,
        entropy_trace: Sequence[float],
        exit_layer: Optional[int] = None,
        *,
        target_latency_s: Optional[float] = None,
    ) -> DVFSReport:
        """Run Alg. 1 for one sentence given its per-layer off-ramp entropies.

        ``entropy_trace[i]`` is the entropy after layer i+1; the trace ends at
        the layer the sentence exited (``exit_layer`` defaults to its length).
        ``target_latency_s`` overrides the controller-global target with a
        per-request deadline (the serving engine passes ``Request.deadline_s``).
        """
        target = (
            self.target_latency_s if target_latency_s is None else float(target_latency_s)
        )
        assert target > 0
        if exit_layer is None:
            exit_layer = len(entropy_trace)
        assert exit_layer >= 1 and len(entropy_trace) >= 1
        t_max = self.layer_time_s(self.max_op)
        e_max = self.layer_energy(self.max_op)

        # line 1: the first layer always runs at the nominal/maximum point
        latency = t_max
        energy = e_max
        if exit_layer == 1:
            return DVFSReport(
                exit_layer=1,
                predicted_exit=1.0,
                op=self.max_op,
                latency_s=latency,
                energy_j=energy,
                deadline_met=latency <= target * (1 + 1e-9),
                energy_max_freq_j=e_max,
                escalated_layers=0,
            )

        # line 2: predict the total exit layer from the first off-ramp entropy
        predicted = max(self.predict(entropy_trace[0]), 2.0)
        # lines 3-4: slowest (V, f) finishing the predicted remainder in time
        op = self.select_op(predicted - 1.0, target - latency)

        escalated = 0
        for li in range(2, exit_layer + 1):
            # misprediction guard: past the predicted exit, bound the latency
            # by escalating to the maximum operating point
            cur = op if li <= predicted + 1e-9 else self.max_op
            if cur is self.max_op and li > predicted:
                escalated += 1
            latency += self.layer_time_s(cur)
            energy += self.layer_energy(cur)
        return DVFSReport(
            exit_layer=int(exit_layer),
            predicted_exit=predicted,
            op=op,
            latency_s=latency,
            energy_j=energy,
            deadline_met=latency <= target * (1 + 1e-9),
            energy_max_freq_j=exit_layer * e_max,
            escalated_layers=escalated,
        )

    # ----------------------------------------------------------- baselines
    def no_early_exit_baseline(self) -> Dict[str, float]:
        """Conventional inference: all n_layers, always at the max point."""
        L = self.stats.n_layers
        return {
            "latency_s": L * self.layer_time_s(self.max_op),
            "energy_j": L * self.layer_energy(self.max_op),
        }  # == module-level no_early_exit_baseline(self.stats) at defaults

    def max_freq_early_exit_baseline(self, exit_layers: Sequence[int]) -> Dict[str, float]:
        """Latency-unbounded early exit: race to the exit at max V/f."""
        t = self.layer_time_s(self.max_op)
        e = self.layer_energy(self.max_op)
        exits = np.asarray(list(exit_layers), np.float64)
        return {
            "latency_s": float(exits.max() * t) if exits.size else 0.0,
            "energy_j": float(exits.sum() * e),
        }


# ===========================================================================
# Batched shared-clock arbitration (single LDO/ADPLL across all lanes)
# ===========================================================================


@dataclass
class _LaneClock:
    """Arbiter-side state of one in-flight lane."""

    admit_s: float                        # modeled admission time
    deadline_s: float                     # admit + this lane's OWN target
    target_s: float                       # the lane's latency budget (per-
                                          # request SLO or controller target)
    cycles_per_layer: float               # this lane's BUCKET layer cost
    depth: int = 0                        # layers completed (decode lanes:
                                          # summed over the tokens generated)
    tokens: int = 0                       # decode lanes: tokens ACCEPTED so
                                          # far (speculative fused steps may
                                          # accept several per step; depth
                                          # stays the layer-true energy/clock
                                          # integral while tokens carries the
                                          # throughput the DVFS re-budget and
                                          # the bench gates reason about)
    predicted_exit: Optional[float] = None  # set after the first off-ramp
    first_entropy: Optional[float] = None
    energy_j: float = 0.0
    # per-lane power ratio vs the controller anchor (compressed deployments:
    # sparsity/span power gating the cycles ratio alone cannot express)
    energy_scale: float = 1.0
    slowest_op: Optional[OperatingPoint] = None
    # decode lanes: predicted layers still to run across ALL remaining tokens
    # (position-binned per-token exit predictions, conservative full depth
    # cold).  When set it REPLACES the classifier entropy-LUT chain in
    # ``required_hz`` — the engine refreshes it before every fused step.
    pred_layers_remaining: Optional[float] = None


@dataclass
class ArbiterStepDecision:
    """Outcome of one fused-step arbitration."""

    op: OperatingPoint
    dt_s: float                           # step duration incl. any switch stall
    switched: bool
    need_hz: Dict[int, float]             # per-lane required frequency (inf =
                                          # first layer / escalation / no slack)


@dataclass
class LaneDVFSReport:
    """Per-sentence outcome under shared-clock arbitration."""

    exit_layer: int
    predicted_exit: float
    latency_s: float
    energy_j: float
    deadline_met: bool
    escalated_layers: int
    slowest_op: OperatingPoint            # lowest point the sentence ran at
    target_s: float = 0.0                 # the deadline the lane was judged by


class BatchedDVFSArbiter:
    """ONE (V, f) decision per fused step across all in-flight lanes.

    The EdgeBERT accelerator has a single LDO/ADPLL pair, so a batched
    deployment cannot replay Alg. 1 per sentence — the clock is shared.  The
    arbiter generalizes Alg. 1 to the lane set: every fused step it computes
    each active lane's *required* frequency (predicted remaining layers over
    remaining time-to-deadline, exactly Alg. 1 lines 3-4 evaluated live) and
    drives the shared clock at the slowest table point satisfying the MAX of
    those requirements.  Lanes that have not evaluated their first off-ramp
    yet (Alg. 1 line 1) and lanes past their predicted exit (misprediction
    escalation) require the maximum point.  Every operating-point change is
    charged the LDO/ADPLL switching stall (`hwmodel.op_switch_overhead`) —
    the cost a per-sentence replay never models.

    Per-request deadlines: ``admit`` accepts the lane's OWN latency budget
    (``deadline_s``; the serving engine passes ``Request.deadline_s``), so
    the shared-clock decision maximizes slack per lane against THAT lane's
    deadline — the controller-global target is only the fallback.  It also
    accepts the lane's bucket-specific ``cycles_per_layer``: required
    frequency, step duration, and energy are all budgeted at the lane's OWN
    bucket cost instead of the largest bucket's.

    Lane keys are opaque hashables — the engine uses (server, bucket, lane)
    tuples because cross-bucket time slicing keeps several buckets' lanes in
    flight at once.

    The arbiter advances a MODELED clock (`now_s`); per-sentence latency is
    measured from lane admission, matching the per-sentence controller's
    accounting (queue wait is a scheduler concern, not a DVFS one).
    """

    def __init__(self, controller: LatencyAwareDVFSController):
        self.c = controller
        self.now_s = 0.0
        self.cur_op: Optional[OperatingPoint] = None
        self._lanes: Dict[int, _LaneClock] = {}
        # ---- drain-level telemetry ----
        self.op_switches = 0
        self.switch_time_s = 0.0
        self.switch_energy_j = 0.0
        self.compute_energy_j = 0.0
        self.steps = 0
        self.lane_steps = 0          # lane participations summed over steps
        self.tokens_accepted = 0     # decode tokens accepted (spec blocks
                                     # count every accepted token)

    # ------------------------------------------------------------ lifecycle
    def admit(
        self,
        lane,
        *,
        deadline_s: Optional[float] = None,
        cycles_per_layer: Optional[float] = None,
        energy_scale: float = 1.0,
    ) -> None:
        """A request entered a lane: its deadline clock starts now.

        ``deadline_s``: this lane's OWN latency budget (``Request.deadline_s``);
        ``None`` falls back to the controller-global target.
        ``cycles_per_layer``: the lane's bucket-specific layer cost; ``None``
        uses the controller's (largest-bucket) stats.
        ``energy_scale``: this lane's per-layer POWER ratio against the
        controller anchor.  Compressed deployments (pruning/span) gate power
        beyond what the cycles ratio captures — the engine passes
        P(task stats)/P(anchor stats) so lane energy prices the task's actual
        sparse network.
        """
        assert lane not in self._lanes, f"lane {lane} already in flight"
        target = self.c.target_latency_s if deadline_s is None else float(deadline_s)
        assert target > 0
        assert energy_scale > 0
        self._lanes[lane] = _LaneClock(
            admit_s=self.now_s,
            deadline_s=self.now_s + target,
            target_s=target,
            cycles_per_layer=(
                self.c.cycles_per_layer if cycles_per_layer is None
                else float(cycles_per_layer)
            ),
            energy_scale=float(energy_scale),
        )

    def observe_entropy(self, lane, entropy: float) -> None:
        """First off-ramp evaluated: Alg. 1 line 2 prediction for this lane."""
        st = self._lanes[lane]
        if st.predicted_exit is None:
            st.first_entropy = float(entropy)
            st.predicted_exit = max(self.c.predict(entropy), float(st.depth + 1))

    def set_remaining_layers(self, lane, layers: float) -> None:
        """Decode lanes: refresh the predicted layers this lane still needs
        across ALL its remaining tokens (the engine sums its position-binned
        per-token exit predictions, conservative full depth per token while
        the calibrator is cold).  Overrides the classifier entropy-LUT chain
        in ``required_hz`` — per-token escalation is folded into the
        prediction itself (the calibrator's quantile tracks realized depths,
        and every fused step re-budgets from the refreshed value)."""
        self._lanes[lane].pred_layers_remaining = max(float(layers), 0.0)

    def required_hz(self, lane) -> float:
        """Frequency this lane needs from the SHARED clock right now.

        Before the first off-ramp there is no prediction (Alg. 1 line 1), so
        the lane conservatively budgets the FULL remaining depth — at a
        slack-free target that is exactly the nominal frequency, the paper's
        run-layer-1-at-nominal rule, and it scales down when the target has
        headroom.  inf encodes 'maximum point, unconditionally': a lane past
        its predicted exit escalates (misprediction guard), and exhausted
        slack leaves no choice.  Remaining work is costed at the lane's OWN
        bucket cycles and judged against the lane's OWN deadline.

        Decode lanes (``set_remaining_layers``) substitute the token-level
        predicted remainder for the classifier entropy chain — same
        remaining-cycles-over-remaining-time rule, Alg. 1 lines 3-4 on the
        token timeline.
        """
        st = self._lanes[lane]
        if st.pred_layers_remaining is not None:
            t_rem = st.deadline_s - self.now_s
            if t_rem <= 0:
                return float("inf")
            return st.pred_layers_remaining * st.cycles_per_layer / t_rem
        predicted = st.predicted_exit
        if predicted is None:
            predicted = float(self.c.stats.n_layers)   # conservative line 1
        elif st.depth + 1 > predicted + 1e-9:
            return float("inf")          # escalation: past the predicted exit
        t_rem = st.deadline_s - self.now_s
        if t_rem <= 0:
            return float("inf")
        remaining = predicted - st.depth
        return remaining * st.cycles_per_layer / t_rem

    def step(
        self, active_lanes: Sequence, layers: Optional[Dict] = None,
        *, floor_hz: float = 0.0, tokens: Optional[Dict] = None,
    ) -> ArbiterStepDecision:
        """Arbitrate + account ONE fused step over ``active_lanes``.

        The scheduler steps one bucket at a time, so the stepped lanes share
        a bucket; the step duration is that bucket's layer time (max over the
        stepped lanes' cycle costs) and each lane's energy is charged at its
        own bucket's cost.

        ``layers`` (optional): layers each lane actually executed this fused
        step.  Classifier fused steps run exactly ONE encoder layer per lane
        (the default); a decode fused step runs one TOKEN per lane, whose
        realized cost is that token's early-exit depth — the engine passes
        ``{lane: exit_depth}`` so energy and step duration charge only the
        layers the off-ramp let run.  The (V, f) decision itself is made
        from pre-step state (the refreshed per-lane predictions), exactly as
        in the per-layer case.

        ``floor_hz``: barrier-aware pacing for replicated clock domains.  The
        fused step is SPMD — every replica leaves the collective together, so
        the FLEET step lasts as long as its slowest domain.  Running a domain
        slower than the fleet's tightest lane requirement saves no energy
        (the tight domain sets the wall time either way) and silently spends
        OTHER domains' deadline slack through the barrier, so the engine
        passes the fleet-wide max required frequency as a floor on every
        domain's pick.  Single-domain serving passes nothing: the floor
        degenerates to this arbiter's own requirement.

        ``tokens`` (optional): tokens each lane ACCEPTED this fused step.
        A speculative decode step accepts a block, so its lane runs
        ``sum(block exit depths)`` layers but advances several tokens — the
        engine passes ``{lane: accepted}`` alongside ``layers`` so the
        arbiter's throughput telemetry (tokens per lane-step) prices the
        clock's work in tokens while energy/time stay layer-true.
        """
        lanes = list(active_lanes)
        assert lanes, "step() needs at least one active lane"
        need = {i: self.required_hz(i) for i in lanes}
        op = self.c.op_for_freq(max(max(need.values()), floor_hz))

        switched = self.cur_op is not None and op != self.cur_op
        if switched:
            ov = op_switch_overhead(
                self.cur_op.vdd, self.cur_op.freq_hz, op.vdd, op.freq_hz,
                power_mw_nom=self._power_mw_nom(),
            )
            self.op_switches += 1
            self.switch_time_s += ov["time_s"]
            self.switch_energy_j += ov["energy_j"]
            self.now_s += ov["time_s"]   # the stall spends every lane's slack
        self.cur_op = op

        e_layer = self.c.layer_energy(op)
        step_cycles = 0.0
        for i in lanes:
            st = self._lanes[i]
            nl = 1 if layers is None else int(layers[i])
            assert nl >= 1, f"lane {i}: a fused step runs at least one layer"
            st.depth += nl
            nt = 0 if tokens is None else int(tokens.get(i, 0))
            assert nt <= nl, f"lane {i}: cannot accept more tokens than layers"
            st.tokens += nt
            self.tokens_accepted += nt
            self.lane_steps += 1
            # energy ~ P(V) * cycles / f: scale the controller's per-layer
            # energy by this lane's bucket cycle ratio and its deployment's
            # power ratio (sparsity/span gating vs the anchor stats)
            e_lane = (
                nl * e_layer * st.energy_scale
                * (st.cycles_per_layer / self.c.cycles_per_layer)
            )
            st.energy_j += e_lane
            self.compute_energy_j += e_lane
            step_cycles = max(step_cycles, nl * st.cycles_per_layer)
            if st.slowest_op is None or op.freq_hz < st.slowest_op.freq_hz:
                st.slowest_op = op
        dt = step_cycles / op.freq_hz
        self.now_s += dt
        self.steps += 1
        return ArbiterStepDecision(op=op, dt_s=dt, switched=switched, need_hz=need)

    def advance_to(self, t: float) -> None:
        """Fast-forward the modeled clock to ``t`` (monotone; no-op if behind).

        Replicated serving runs one arbiter per device, but the fused step is
        SPMD: every replica leaves the collective barrier together, so after
        arbitrating its own lanes each replica's clock is pulled up to the
        fleet max.  Waiting at a barrier burns wall time, not operating-point
        changes — no energy or (V, f) state is touched.
        """
        self.now_s = max(self.now_s, float(t))

    def checkpoint_lane(self, lane) -> _LaneClock:
        """Preemption support: detach a lane's clock so the lane index can be
        reused, FREEZING the lane's remaining budget while it sits parked in
        the scheduler queue (parked time is a scheduling decision, not lane
        latency — the DVFS layer keeps budgeting compute only).  The returned
        clock stores elapsed-running-time in ``admit_s`` and budget-left in
        ``deadline_s``; ``restore_lane`` re-anchors both."""
        st = self._lanes.pop(lane)
        st.deadline_s = st.deadline_s - self.now_s    # remaining budget
        st.admit_s = self.now_s - st.admit_s          # elapsed running time
        return st

    def restore_lane(self, lane, clock: _LaneClock) -> None:
        """Re-admit a checkpointed lane clock under a (possibly different)
        lane key: depth, energy, prediction, and slowest-op carry over, the
        deadline re-arms with the frozen remaining budget (floored at a
        sliver: an already-late lane races at max V/f)."""
        assert lane not in self._lanes, f"lane {lane} already in flight"
        clock.admit_s = self.now_s - clock.admit_s
        clock.deadline_s = self.now_s + max(clock.deadline_s, 1e-12)
        self._lanes[lane] = clock

    def min_latency_quote(
        self, predicted_layers: float, cycles_per_layer: Optional[float] = None
    ) -> float:
        """Floor on achievable lane latency: the admission-control quote.

        ``predicted_layers`` at the MAXIMUM operating point — no schedule can
        beat the top table entry — plus ONE worst-case LDO/ADPLL switching
        stall (admitting a slack-free lane may yank the shared clock from the
        table's slowest point to its fastest).  An explicit SLO below this is
        physically infeasible and must be rejected or re-quoted at admission
        time instead of accepted and missed.
        """
        cyc = (
            self.c.cycles_per_layer if cycles_per_layer is None
            else float(cycles_per_layer)
        )
        lo, hi = self.c.table[0], self.c.max_op
        stall = op_switch_overhead(
            lo.vdd, lo.freq_hz, hi.vdd, hi.freq_hz,
            power_mw_nom=self._power_mw_nom(),
        )["time_s"]
        return max(predicted_layers, 0.0) * cyc / hi.freq_hz + stall

    def retire(self, lane, exit_layer: int) -> LaneDVFSReport:
        """Lane exited: close its clock, emit its report, free the lane."""
        st = self._lanes.pop(lane)
        assert st.depth == exit_layer, (st.depth, exit_layer)
        latency = self.now_s - st.admit_s
        predicted = (
            st.predicted_exit if st.predicted_exit is not None else float(exit_layer)
        )
        # layers whose index exceeded the prediction ran escalated (matches
        # the per-sentence controller: li > predicted -> max point)
        escalated = max(0, exit_layer - int(np.floor(predicted + 1e-9)))
        # online calibration: the retired sentence feeds the running LUT
        if st.first_entropy is not None:
            self.c.observe_exit(st.first_entropy, exit_layer)
        return LaneDVFSReport(
            exit_layer=int(exit_layer),
            predicted_exit=predicted,
            latency_s=latency,
            energy_j=st.energy_j,
            deadline_met=latency <= st.target_s * (1 + 1e-9),
            escalated_layers=escalated,
            slowest_op=st.slowest_op if st.slowest_op is not None else self.c.max_op,
            target_s=st.target_s,
        )

    # ------------------------------------------------------------ accounting
    def _power_mw_nom(self) -> float:
        return accel_power_mw(self.c.stats, self.c.n)["total"]

    @property
    def in_flight(self) -> int:
        return len(self._lanes)

    @property
    def total_energy_j(self) -> float:
        """Compute + switching energy of everything arbitrated so far."""
        return self.compute_energy_j + self.switch_energy_j

    def telemetry(self) -> Dict[str, float]:
        return {
            "arb_steps": self.steps,
            "op_switches": self.op_switches,
            "switch_time_s": self.switch_time_s,
            "switch_energy_j": self.switch_energy_j,
            "compute_energy_j": self.compute_energy_j,
            "total_energy_j": self.total_energy_j,
            "modeled_time_s": self.now_s,
            "lane_steps": self.lane_steps,
            "tokens_accepted": self.tokens_accepted,
            "tokens_per_lane_step": (
                self.tokens_accepted / self.lane_steps if self.lane_steps else 0.0
            ),
        }

    # ------------------------------------------------------------- batch API
    def replay_batch(
        self,
        entropy_traces: Sequence[Sequence[float]],
        exit_layers: Sequence[int],
        deadlines_s: Optional[Sequence[Optional[float]]] = None,
    ) -> List[LaneDVFSReport]:
        """Arbitrate a lock-step batch (the kernel-path ``classify`` schedule).

        All sentences are admitted at once (no refill — the deployed
        accelerator's layer-serial batch), stepped together while active, and
        retired at their recorded exit layers.  This is the batched
        counterpart of replaying ``sentence_report`` per sentence.
        ``deadlines_s`` gives each sentence its own latency budget (``None``
        entries fall back to the controller target).
        """
        assert self.in_flight == 0, "replay_batch needs an idle arbiter"
        exits = [int(e) for e in exit_layers]
        assert len(entropy_traces) == len(exits) and all(e >= 1 for e in exits)
        assert deadlines_s is None or len(deadlines_s) == len(exits)
        for i in range(len(exits)):
            self.admit(
                i, deadline_s=None if deadlines_s is None else deadlines_s[i]
            )
        reports: Dict[int, LaneDVFSReport] = {}
        depth = 0
        while True:
            active = [i for i, e in enumerate(exits) if depth < e]
            if not active:
                break
            self.step(active)
            depth += 1
            for i in active:
                if depth == 1:
                    self.observe_entropy(i, entropy_traces[i][0])
                if depth == exits[i]:
                    reports[i] = self.retire(i, depth)
        return [reports[i] for i in range(len(exits))]


def calibrate_predictor(
    model, params, batches, n_bins: int = 16, quantile: Optional[float] = None
) -> ExitPredictor:
    """Fit the Alg. 1 LUT from dense profiling passes (offline calibration).

    ``batches`` is an iterable of ``{"tokens": [B, S]}``-style dicts; the
    model's dense all-layers forward provides (first-off-ramp entropy, exit
    layer) pairs at the configured entropy threshold.  ``quantile`` picks the
    conservative per-bin prediction (see ``fit_exit_predictor``).
    """
    import jax.numpy as jnp

    ents: List[np.ndarray] = []
    exits: List[np.ndarray] = []
    for b in batches:
        out = model.apply_train(params, {"tokens": jnp.asarray(b["tokens"])})
        assert out.all_entropies is not None and out.exit_layer is not None
        ents.append(np.asarray(out.all_entropies[0]))
        exits.append(np.asarray(out.exit_layer))
    return fit_exit_predictor(
        np.concatenate(ents), np.concatenate(exits), n_bins=n_bins, quantile=quantile
    )


def default_albert_controller(
    target_latency_s: float,
    *,
    seq_len: int = 128,
    n: int = 16,
    n_layers: int = 12,
    avg_exit_layer: Optional[float] = None,
    predictor: Optional[ExitPredictor] = None,
    online_calibrator: Optional[OnlineExitCalibrator] = None,
) -> LatencyAwareDVFSController:
    """Controller over the analytic ALBERT-base layer workload (Fig. 8)."""
    stats = albert_layer_stats(seq_len=seq_len)
    stats.n_layers = n_layers
    if avg_exit_layer is not None:
        stats.avg_exit_layer = avg_exit_layer
    return LatencyAwareDVFSController(
        stats, target_latency_s, n=n, predictor=predictor,
        online_calibrator=online_calibrator,
    )
