"""Fused masked-softmax + entropy Pallas kernel.

Implements the paper's GB peripherals verbatim: Algorithm 1 (max trick +
LogSumExp softmax, then element-wise attention-span mask modulation) and the
Eq. 4 entropy as a fused by-product — the EdgeBERT accelerator computes these
back-to-back in the same unit, so one VMEM round-trip serves both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sm_ent_kernel(x_ref, mask_ref, p_ref, h_ref):
    x = x_ref[...].astype(jnp.float32)                 # [R, N]
    # Step 1: max trick
    m = jnp.max(x, axis=-1, keepdims=True)
    z = x - m
    # Step 2: log-exponential-sum
    e = jnp.exp(z)
    s = jnp.sum(e, axis=-1, keepdims=True)
    # Step 3: softmax + span-mask modulation
    probs = e / s
    p_ref[...] = (probs * mask_ref[...].astype(jnp.float32)).astype(p_ref.dtype)
    # Eq. 4 entropy (of the unmasked distribution)
    ent = jnp.log(s[:, 0]) - jnp.sum(z * e, axis=-1) / s[:, 0]
    h_ref[...] = jnp.maximum(ent, 0.0)


def softmax_entropy(
    logits: jnp.ndarray,          # [rows, n]
    mask: jnp.ndarray,            # [rows, n] (ones for pure softmax)
    *,
    block_rows: int = 256,
    interpret: bool = True,
):
    rows, n = logits.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    n_blocks = logits.shape[0] // block_rows

    probs, ent = pl.pallas_call(
        _sm_ent_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(logits.shape, logits.dtype),
            jax.ShapeDtypeStruct((logits.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(logits, mask)
    return probs[:rows], ent[:rows]
