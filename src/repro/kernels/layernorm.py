"""Fused LayerNorm Pallas kernel (paper §V-D3: GB LayerNorm unit, Eq. 5).

Single pass per row tile: accumulate E[X] and E[X^2] over the feature dim in
fp32 (the accelerator's running-moment formulation), normalize, fuse gamma/
beta.  Rows are tiled (block_rows, d) in VMEM; d is kept whole per tile (MXU-
aligned models have d a multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(x * x, axis=-1, keepdims=True) - mean * mean
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def layernorm(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: [rows, d] (callers flatten leading dims)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_blocks = x.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, gamma, beta)
    return out[:rows]
