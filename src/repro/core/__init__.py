"""EdgeBERT core algorithms (the paper's contribution), in pure JAX.

Perf-critical variants live in repro.kernels as Pallas TPU kernels; everything
here is the reference/algorithmic layer used by the model zoo and training.
"""
from repro.core.entropy import entropy_from_logits
from repro.core.adaptivfloat import (
    AFFormat,
    af_decode,
    af_encode,
    af_quantize,
    quantize_pytree,
)
from repro.core.adaptive_span import (
    span_soft_mask,
    span_loss,
    hard_spans,
    active_head_indices,
)
