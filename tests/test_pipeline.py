"""Pipeline parallelism: GPipe schedule over a `stage` mesh axis equals the
sequential layer stack (subprocess: needs forced multi-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.common.jax_compat import HAS_AXIS_TYPES

pytestmark = [
    pytest.mark.multidevice,
    pytest.mark.skipif(
        not HAS_AXIS_TYPES,
        reason="installed jax lacks jax.sharding.AxisType, which the "
        "forced-multi-device subprocess snippet requires",
    ),
]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.training.pipeline import pipeline_forward

        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        # one linear+tanh layer per stage
        ws = jax.random.normal(ks[0], (n_stages, d, d)) / np.sqrt(d)
        x = jax.random.normal(ks[1], (n_micro, mb, d))

        layer_fn = lambda w, h: jnp.tanh(h @ w)
        out = pipeline_forward(layer_fn, ws, x, mesh)

        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = jax.vmap(lambda h: layer_fn(ws[s], h))(ref)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("PIPELINE_OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout
