"""Named, JSON-able workload scenarios and their conversion to live configs.

A scenario spec is deliberately SCALE-FREE: arrival intensity is given as a
``load`` fraction of the serving stack's conservative capacity (full
predicted depth at the max operating point, every lane busy), and MMPP
dwell / diurnal period are given in expected REQUESTS rather than seconds.
``build_workload`` converts a spec into absolute rates against the actual
hardware model, so the same scenario exercises the same queueing regime on
any controller, bucket set, or lane count.

Spec shape (all JSON types, so specs can live in files or CI args)::

    {
      "description": "...",
      "requests":  100000,          # default trace length
      "seed":      0,               # default seed
      "buckets":   [16, 32],        # serving buckets == length support
      "lengths":   [[16, 0.7], [32, 0.3]],      # (bucket, weight) mixture
      "tiers":     [["explicit", 0.35, 80.0],   # (name, weight, slo_mult)
                    ["best_effort", 0.65, null]],   # null => no deadline
      "tasks":     [["mnli", 0.48], ...],       # skewed popularity; [] =
                                                #   single-task traffic
      "sram_tasks": 2,              # SRAM working set (multi-task only)
      "arrivals": {"kind": "poisson", "load": 0.55}
                | {"kind": "mmpp", "loads": [...], "mean_dwell_requests": [...]}
                | {"kind": "diurnal", "load": 0.5, "depth": 0.6,
                   "period_requests": 5000}
    }

An explicit tier's ``slo_mult`` is the deadline in multiples of the
request's OWN full-depth service time (admission quotes then add queueing
and swap terms on top), so SLO tightness is also scale-free.

Add a scenario by appending a spec here — the harness CLI, CI smoke gates
and the BENCH history pick it up by name.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

from repro.serving.workload import (
    DiurnalArrivals,
    MMPPArrivals,
    PoissonArrivals,
    TierSpec,
    WorkloadConfig,
)

# Zipf(1)-style popularity over four GLUE-ish tasks: 1/k weights, normalized
_ZIPF4 = (("mnli", 0.48), ("qqp", 0.24), ("sst2", 0.16), ("qnli", 0.12))

SCENARIOS: Dict[str, Dict[str, Any]] = {
    "poisson_singletask": {
        "description": (
            "Steady memoryless load on one task: explicit-SLO and "
            "best-effort tiers over two length buckets at ~55% of "
            "conservative capacity."
        ),
        "requests": 100_000,
        "seed": 0,
        "buckets": [16, 32],
        "lengths": [[16, 0.6], [32, 0.4]],
        "tiers": [["explicit", 0.4, 80.0], ["best_effort", 0.6, None]],
        "tasks": [],
        "arrivals": {"kind": "poisson", "load": 0.55},
    },
    "mmpp_multitask": {
        "description": (
            "Bursty MMPP arrivals (calm ~45% / burst ~180% of conservative "
            "capacity) over four tasks with Zipf-skewed popularity sharing "
            "an SRAM working set that fits two — the full admission -> "
            "residency -> schedule -> DVFS gauntlet."
        ),
        "requests": 100_000,
        "seed": 0,
        "buckets": [16, 32],
        "lengths": [[16, 0.7], [32, 0.3]],
        "tiers": [["explicit", 0.35, 80.0], ["best_effort", 0.65, None]],
        "tasks": [list(t) for t in _ZIPF4],
        "sram_tasks": 2,
        # contract safety under sustained bursts: extra-conservative quotes
        # (the per-task quote cannot price the affinity policy's legal
        # deferral of a non-resident task) and a positive swap-preemption
        # margin (in full-depth services) so urgent non-resident tasks swap
        # in EARLY enough to cover their remaining compute.  The margin must
        # cover EVERY simultaneously-urgent non-resident task, not just one:
        # with 4 tasks on 2 SRAM slots a burst can make both out-of-SRAM
        # tasks urgent at once, and the second waits a full swap + service
        # behind the first — hence 2 slots x 4 services.
        "admission_headroom": 2.0,
        "affinity_margin_services": 8.0,
        "arrivals": {
            "kind": "mmpp",
            "loads": [0.45, 1.8],
            "mean_dwell_requests": [400, 120],
        },
    },
    "diurnal_tiered": {
        "description": (
            "Sinusoid-modulated day/night envelope (50% +- 60% of "
            "conservative capacity) with three tiers: premium tight-SLO, "
            "standard loose-SLO, best-effort."
        ),
        "requests": 100_000,
        "seed": 0,
        "buckets": [16, 32],
        "lengths": [[16, 0.5], [32, 0.5]],
        "tiers": [
            ["premium", 0.15, 40.0],
            ["standard", 0.35, 160.0],
            ["best_effort", 0.5, None],
        ],
        "tasks": [],
        "arrivals": {
            "kind": "diurnal", "load": 0.5, "depth": 0.6,
            "period_requests": 5000,
        },
    },
}


def full_depth_service_s(ctrl, n_layers: int, buckets) -> Callable[[int], float]:
    """Price one request's FULL-DEPTH service at the max operating point,
    at its own bucket's cycle cost — the scale-free SLO/capacity unit."""
    bs = tuple(sorted(int(b) for b in buckets))

    def service_s(length: int) -> float:
        b = next((x for x in bs if x >= int(length)), bs[-1])
        return float(n_layers) * ctrl.cycles_for_seq_len(b) / ctrl.max_op.freq_hz

    return service_s


def capacity_rps(ctrl, n_layers: int, lanes: int, lengths) -> float:
    """Conservative sustainable rate: every lane busy, every request at full
    predicted depth, weighted by the scenario's length mixture.  Early exit
    makes the TRUE capacity higher, so a ``load`` of 1.0 is a heavy-but-
    drainable regime, not a hard wall."""
    svc = full_depth_service_s(ctrl, n_layers, [b for b, _ in lengths])
    wsum = sum(w for _, w in lengths)
    mean_svc = sum(w * svc(b) for b, w in lengths) / wsum
    return float(lanes) / mean_svc


def build_workload(
    spec: Dict[str, Any],
    *,
    ctrl,
    n_layers: int,
    lanes: int,
    seed: Optional[int] = None,
) -> WorkloadConfig:
    """Convert a scale-free scenario spec into a ``WorkloadConfig`` with
    absolute rates calibrated against this controller's capacity."""
    lengths: Tuple[Tuple[int, float], ...] = tuple(
        (int(b), float(w)) for b, w in spec["lengths"]
    )
    cap = capacity_rps(ctrl, n_layers, lanes, lengths)
    a = spec["arrivals"]
    kind = a["kind"]
    if kind == "poisson":
        arrivals = PoissonArrivals(rate_hz=float(a["load"]) * cap)
    elif kind == "mmpp":
        rates = tuple(float(l) * cap for l in a["loads"])
        dwell = tuple(
            float(n) / r for n, r in zip(a["mean_dwell_requests"], rates)
        )
        arrivals = MMPPArrivals(rates_hz=rates, mean_dwell_s=dwell)
    elif kind == "diurnal":
        base = float(a["load"]) * cap
        arrivals = DiurnalArrivals(
            base_rate_hz=base,
            period_s=float(a["period_requests"]) / base,
            depth=float(a["depth"]),
        )
    else:
        raise ValueError(f"unknown arrival kind: {kind!r}")
    tiers = tuple(
        TierSpec(str(n), float(w), None if m is None else float(m))
        for n, w, m in spec["tiers"]
    )
    tasks = tuple((str(t), float(w)) for t, w in spec.get("tasks", []))
    return WorkloadConfig(
        arrivals=arrivals, lengths=lengths, tiers=tiers, tasks=tasks,
        seed=int(spec.get("seed", 0) if seed is None else seed),
    )
