"""Paper Table I: learned per-head attention spans + FLOP reduction.

Reports (a) the spans our span-regularized fine-tuning actually learns on the
toy task, (b) the paper's published MNLI/QQP/SST-2/QNLI spans pushed through
the deployment path (head gathering + windowed kernel) with the resulting
attention-FLOP factor.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us, trained_albert
from repro.core.adaptive_span import active_head_indices, hard_spans, span_flop_factor

PAPER_SPANS = {
    "mnli": [20, 0, 0, 0, 0, 0, 36, 81, 0, 0, 0, 10],
    "qqp": [16, 0, 0, 0, 0, 0, 40, 75, 0, 0, 0, 2],
    "sst2": [31, 0, 0, 0, 0, 101, 14, 5, 0, 36, 0, 0],
    "qnli": [39, 0, 0, 0, 0, 105, 22, 19, 0, 51, 0, 0],
}


def main() -> None:
    model, params, _, data, cfg = trained_albert()
    learned = hard_spans(np.asarray(params["span_z"])[0])
    idx, window = active_head_indices(learned)
    emit(
        "table1_learned_spans", 0.0,
        f"spans={list(learned)};active={len(idx)}/{cfg.n_heads};"
        f"avg={learned.mean():.1f}",
    )
    for task, spans in PAPER_SPANS.items():
        f = span_flop_factor(spans, 12, 128)
        active, window = active_head_indices(spans)
        emit(
            f"table1_paper_{task}", 0.0,
            f"heads_on={len(active)}/12;avg_span={np.mean(spans):.1f};"
            f"score_flops_kept={f:.3f}",
        )


if __name__ == "__main__":
    main()
