"""Bitmask sparse encoding (paper §V-C): binary tags for zero/non-zero entries;
only non-zeros are stored.  This is the *storage* format (checkpoint + eNVM
accounting, the paper's 12% overhead figure); compute-side sparsity is handled
at tile granularity by the block-sparse Pallas kernel (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np


class BitmaskEncoded(NamedTuple):
    bitmask: np.ndarray      # packed uint8, 1 bit per element (stored in SLC)
    values: np.ndarray       # non-zero values in row-major order
    shape: Tuple[int, ...]
    dtype: np.dtype


def encode(arr: np.ndarray) -> BitmaskEncoded:
    arr = np.asarray(arr)
    flat = arr.reshape(-1)
    nz = flat != 0
    return BitmaskEncoded(
        bitmask=np.packbits(nz),
        values=flat[nz].copy(),
        shape=arr.shape,
        dtype=arr.dtype,
    )


def decode(enc: BitmaskEncoded) -> np.ndarray:
    n = int(np.prod(enc.shape))
    nz = np.unpackbits(enc.bitmask, count=n).astype(bool)
    out = np.zeros(n, dtype=enc.dtype)
    out[nz] = enc.values
    return out.reshape(enc.shape)


def storage_bytes(enc: BitmaskEncoded, value_bits: int = 8) -> dict:
    """Storage accounting: paper reports the bitmask as a 12% overhead on top
    of 8-bit non-zero values at 60% embedding sparsity (1 bit per element ~=
    12.5% of the dense 8-bit footprint; relative to the 40%-density value
    payload it is ~31%)."""
    n = int(np.prod(enc.shape))
    mask_bytes = len(enc.bitmask)
    value_bytes = len(enc.values) * value_bits // 8
    dense_bytes = n * value_bits // 8
    return {
        "mask_bytes": mask_bytes,
        "value_bytes": value_bytes,
        "total_bytes": mask_bytes + value_bytes,
        "dense_bytes": dense_bytes,
        "compression": dense_bytes / max(mask_bytes + value_bytes, 1),
        "mask_overhead_vs_dense": mask_bytes / max(dense_bytes, 1),
    }
