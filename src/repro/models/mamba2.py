"""Mamba2 (SSD — state-space duality) block, chunked-parallel formulation.

Recurrence per head h with state S_t in R^{P x N} (P=head dim, N=ssm_state):

    S_t = exp(a_h * dt_t) * S_{t-1} + dt_t * x_t B_t^T
    y_t = S_t^T-contract:  y_t = C_t @ S_t^T ... (y_t[p] = sum_n S_t[p,n] C_t[n])
    out = y + D * x

The chunked algorithm (Mamba2 paper §6) splits the sequence into chunks of
length Q: intra-chunk contributions via a masked [Q, Q] decay matrix (dual
"linear attention" form) and inter-chunk via a state carried between chunks
with a `lax.scan`.  The scan-free intra-chunk math is MXU-friendly; this jnp
implementation is the oracle for a potential Pallas port and is exact vs the
step-by-step recurrence (tested).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, Any]

CONV_K = 4  # depthwise causal conv width (mamba default)


def d_inner(cfg) -> int:
    return 2 * cfg.d_model


def n_ssm_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(rng, cfg, dtype) -> Params:
    d = cfg.d_model
    di = d_inner(cfg)
    H = n_ssm_heads(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(rng, 6)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (CONV_K, di + 2 * N), dtype, scale=0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "w_out": dense_init(ks[2], (di, d), dtype),
    }


def _ssd_chunked(
    x: jnp.ndarray,    # [B, S, H, P]
    dt: jnp.ndarray,   # [B, S, H]  (softplus'd, > 0)
    a: jnp.ndarray,    # [H]        (negative decay rates)
    Bm: jnp.ndarray,   # [B, S, N]
    Cm: jnp.ndarray,   # [B, S, N]
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    Q = chunk

    xs = x.reshape(B, nc, Q, H, P)
    dts = dt.reshape(B, nc, Q, H)
    Bs = Bm.reshape(B, nc, Q, N)
    Cs = Cm.reshape(B, nc, Q, N)

    # log-decay per step: da[b,c,q,h] = a[h] * dt
    da = a[None, None, None, :] * dts                      # <= 0
    cum = jnp.cumsum(da, axis=2)                           # within-chunk cumulative
    chunk_total = cum[:, :, -1, :]                         # [B, nc, H]

    # intra-chunk: y_intra[q] = sum_{s<=q} C_q.B_s * exp(cum_q - cum_s) * dt_s * x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,nc,Q(q),Q(s),H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cs, Bs)             # [B,nc,Q,Q]
    w = cb[..., None] * decay * dts[:, :, None, :, :]      # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, xs)

    # chunk-end states: S_c = sum_s exp(cum_Q - cum_s) * dt_s * x_s B_s^T
    state_decay = jnp.exp(chunk_total[:, :, None, :] - cum)        # [B,nc,Q,H]
    su = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", state_decay * dts, xs, Bs)

    # inter-chunk scan over nc
    def scan_fn(prev, inp):
        su_c, tot_c = inp                                   # [B,H,P,N], [B,H]
        new = prev * jnp.exp(tot_c)[:, :, None, None] + su_c
        return new, prev                                    # emit state BEFORE chunk

    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn,
        init_state.astype(jnp.float32),
        (su.transpose(1, 0, 2, 3, 4).astype(jnp.float32), chunk_total.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [B,nc,H,P,N]

    # inter-chunk contribution: y_inter[q] = C_q @ (exp(cum_q) * S_prev)^T
    inter_decay = jnp.exp(cum)                               # [B,nc,Q,H]
    y_inter = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Cs, prev_states.astype(jnp.float32), inter_decay
    )

    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final.astype(x.dtype)


def _ssd_step(
    state: jnp.ndarray,  # [B, H, P, N]
    x: jnp.ndarray,      # [B, H, P]
    dt: jnp.ndarray,     # [B, H]
    a: jnp.ndarray,      # [H]
    Bm: jnp.ndarray,     # [B, N]
    Cm: jnp.ndarray,     # [B, N]
):
    """Single-token recurrent step (decode)."""
    decay = jnp.exp(a[None, :] * dt)                        # [B, H]
    state = state * decay[:, :, None, None] + (
        (dt[:, :, None] * x)[..., None] * Bm[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    return state, y


def apply_mamba2(
    p: Params,
    u: jnp.ndarray,        # [B, S, d]
    cfg,
    *,
    conv_state: Optional[jnp.ndarray] = None,  # [B, CONV_K-1, di+2N] (decode)
    ssm_state: Optional[jnp.ndarray] = None,   # [B, H, P, N] (decode)
    decode: bool = False,
):
    """Returns (out [B,S,d], (new_conv_state, new_ssm_state))."""
    B, S, d = u.shape
    di = d_inner(cfg)
    H = n_ssm_heads(cfg)
    N = cfg.ssm_state
    P = cfg.ssm_head_dim

    proj = u @ p["w_in"]
    # split: z [0:di] | xbc [di : 2di+2N] | dt [2di+2N :]
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * N]
    dt_raw = proj[..., 2 * di + 2 * N :]

    # depthwise causal conv over xbc
    if decode:
        assert conv_state is not None
        window = jnp.concatenate([conv_state, xbc], axis=1)      # [B, K-1+S, di+2N]
        new_conv_state = window[:, -(CONV_K - 1) :, :]
        conv_in = window
    else:
        conv_in = jnp.pad(xbc, ((0, 0), (CONV_K - 1, 0), (0, 0)))
        new_conv_state = xbc[:, -(CONV_K - 1) :, :] if S >= CONV_K - 1 else None
    # conv: out[t] = sum_k w[k] * in[t + k]  (causal window ending at t)
    cw = p["conv_w"].astype(jnp.float32)
    conv_out = sum(
        conv_in[:, k : k + (conv_in.shape[1] - CONV_K + 1), :].astype(jnp.float32) * cw[k]
        for k in range(CONV_K)
    )
    conv_out = jax.nn.silu(conv_out).astype(u.dtype)

    x_part = conv_out[..., :di].reshape(B, -1, H, P)
    Bm = conv_out[..., di : di + N]
    Cm = conv_out[..., di + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if decode and S == 1:
        assert ssm_state is not None
        new_state, y = _ssd_step(
            ssm_state.astype(jnp.float32),
            x_part[:, 0].astype(jnp.float32),
            dt[:, 0],
            a,
            Bm[:, 0].astype(jnp.float32),
            Cm[:, 0].astype(jnp.float32),
        )
        y = y[:, None]
    else:
        y, new_state = _ssd_chunked(
            x_part.astype(jnp.float32),
            dt,
            a,
            Bm.astype(jnp.float32),
            Cm.astype(jnp.float32),
            cfg.ssm_chunk,
            init_state=ssm_state,
        )

    y = y + p["d_skip"][None, None, :, None] * x_part.astype(jnp.float32)
    y = y.reshape(B, -1, di).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    out = y @ p["w_out"]
    return out, (new_conv_state, new_state.astype(u.dtype) if new_state is not None else None)
