"""Per-token early exit + DVFS on the decoder serving lane.

The tentpole parity suite: (a) bucketed fused decode WITH per-token exits is
bit-identical (logits, generated tokens, exit depths) to an isolated
per-sequence decode; (b) a preempt/checkpoint/restore cycle mid-generation
with exits live reproduces an uninterrupted run exactly, with zero new
compiled traces; (c) exit-enabled decode under the shared-clock arbiter is
strictly cheaper than full-depth decode at equal (zero) accepted-SLO misses,
and the admission quote prices a cold decoder at conservative full depth.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.early_exit import PositionBinnedExitCalibrator
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.admission import AdmissionController
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import DecoderServer, Request, probe_exit_threshold


def _decoder_model(n_layers=4, seed=1):
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none",
        n_layers=n_layers,
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return model, params, cfg


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(4, cfg.vocab_size, size=L).astype(np.int32) for L in lengths
    ]


def _probe_threshold(model, params, cfg, prompts, q=0.5, max_new=5):
    """The shared probe recipe (``serving.engine.probe_exit_threshold``)."""
    return probe_exit_threshold(
        model, params, prompts, max_new_tokens=max_new, quantile=q
    )


def _reference_ee_decode(model, params, prompt, max_new, bucket, threshold):
    """Isolated single-request early-exit decode — the ground truth a fused
    lane must reproduce bit-for-bit.  Prefill mirrors the engine (full-depth
    ``decode_step`` over the prompt: prompt KV is always exact); generation
    runs ``decode_step_ee`` per token."""
    cache = model.init_cache(1, bucket)
    for t in range(len(prompt) - 1):
        _, cache = model.decode_step(
            params, cache, jnp.asarray([[int(prompt[t])]]), t
        )
    pos, cur = len(prompt) - 1, int(prompt[-1])
    outs, exits, last_logits = [], [], None
    for _ in range(max_new):
        lg, cache, xl, _ = model.decode_step_ee(
            params, cache, jnp.asarray([[cur]]), pos, threshold
        )
        cur = int(jnp.argmax(lg[0, -1]))
        outs.append(cur)
        exits.append(int(xl[0]))
        last_logits = np.asarray(lg[0, -1])
        pos += 1
        if pos >= bucket - 1:
            break
    return outs, exits, last_logits


class TestModelDecodeStepEE:
    def test_no_exit_threshold_matches_decode_step_bitwise(self):
        """threshold below any entropy: every token runs full depth and the
        EE step must be bit-identical to the plain decode step (logits AND
        cache) — the masked off-ramp path may not perturb the math."""
        model, params, cfg = _decoder_model()
        cache = model.init_cache(2, 16)
        toks = jnp.asarray([[5], [9]], jnp.int32)
        lg_ref, cache_ref = model.decode_step(params, cache, toks, 0)
        lg, cache_ee, xl, _ = model.decode_step_ee(params, cache, toks, 0, -1.0)
        assert (np.asarray(xl) == cfg.n_layers).all()
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref))
        for a, b in zip(
            jax.tree_util.tree_leaves(cache_ee), jax.tree_util.tree_leaves(cache_ref)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_inf_threshold_exits_every_token_at_layer_one(self):
        model, params, cfg = _decoder_model()
        cache = model.init_cache(2, 16)
        toks = jnp.asarray([[5], [9]], jnp.int32)
        _, _, xl, fe = model.decode_step_ee(params, cache, toks, 0, np.inf)
        assert (np.asarray(xl) == 1).all()
        assert np.isfinite(np.asarray(fe)).all()

    def test_vmapped_lane_matches_batched_call_bitwise(self):
        """The fused engine vmaps batch-1 calls over lanes; that must compute
        the same bits as the plain batched call (the parity the serving
        tests build on)."""
        model, params, cfg = _decoder_model()
        cache = model.init_cache(2, 16)
        toks = jnp.asarray([[5], [9]], jnp.int32)
        pos = jnp.asarray([0, 0], jnp.int32)
        lane_axes = jax.tree_util.tree_map(lambda _: 1, cache)

        def one_lane(cache_l, tok, p):
            cache_b = jax.tree_util.tree_map(lambda x: x[:, None], cache_l)
            lg, cache_b, xl, fe = model.decode_step_ee(
                params, cache_b, tok[None, None], p, 6.2
            )
            return lg[0], xl[0], fe[0]

        lg_v, xl_v, fe_v = jax.vmap(one_lane, in_axes=(lane_axes, 0, 0))(
            cache, toks[:, 0], pos
        )
        lg_b, _, xl_b, fe_b = model.decode_step_ee(params, cache, toks, 0, 6.2)
        np.testing.assert_array_equal(np.asarray(lg_v), np.asarray(lg_b))
        np.testing.assert_array_equal(np.asarray(xl_v), np.asarray(xl_b))
        np.testing.assert_array_equal(np.asarray(fe_v), np.asarray(fe_b))


class TestFusedDecodeParity:
    def test_bucketed_fused_exits_match_isolated_decode(self):
        """Staggered prompt lengths + continuation refills through the fused
        bucketed EE decode: every request's generated tokens, per-token exit
        depths, and final-token logits must be bit-identical to an isolated
        single-request decode, with ONE decode trace per bucket."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7, 4, 6))
        thr = _probe_threshold(model, params, cfg, prompts)
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=4))
        st = srv.run()
        assert st["completed"] == 5
        assert st["decode_traces_per_bucket"] == {16: 1}
        exits_seen = set()
        for i, p in enumerate(prompts):
            want_toks, want_exits, want_lg = _reference_ee_decode(
                model, params, p, 4, 16, thr
            )
            r = srv.done[i]
            assert r.generated == want_toks, i
            assert r.token_exit_layers == want_exits, i
            # tokens and exit depths are bit-decisions and must be EXACT;
            # raw logits agree to fp tolerance only because the engine's
            # batched prefill fuses differently from the batch-1 reference
            # (same standing as the seed's decoder parity tests)
            np.testing.assert_allclose(r.result, want_lg, atol=1e-4, rtol=1e-5)
            assert int(np.argmax(r.result)) == int(np.argmax(want_lg))
            exits_seen.update(want_exits)
        # the threshold probe guarantees a real spread: some tokens exited
        # early AND some ran deeper, so the parity above is non-trivial
        assert len(exits_seen) > 1

    def test_two_buckets_one_trace_each_with_exits(self):
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (4, 10, 4, 10), seed=3)
        thr = _probe_threshold(model, params, cfg, _prompts(cfg, (6, 5), seed=4))
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=64, eos_id=-1,
            buckets=(8, 16), exit_threshold=thr,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=3))
        st = srv.run()
        assert st["completed"] == 4
        assert st["decode_traces_per_bucket"] == {8: 1, 16: 1}
        for i, p in enumerate(prompts):
            bucket = 8 if len(p) == 4 else 16
            want_toks, want_exits, _ = _reference_ee_decode(
                model, params, p, 3, bucket, thr
            )
            assert srv.done[i].generated == want_toks, i
            assert srv.done[i].token_exit_layers == want_exits, i


class TestCheckpointRestoreParity:
    def test_preempted_decode_with_exits_matches_uninterrupted(self):
        """A mid-generation preempt/checkpoint/restore cycle with per-token
        exits live must reproduce the uninterrupted run exactly — same
        tokens, same exit depths — with zero extra compiled traces."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7), seed=5)
        thr = _probe_threshold(model, params, cfg, prompts)

        # uninterrupted reference drain (same server config, no contract)
        ref = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr, preempt=True,
        )
        for i, p in enumerate(prompts):
            ref.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        ref.run()

        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr, preempt=True,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        srv.step()
        srv.step()
        # a tight contract arrives with every lane busy: one budget-free
        # lane is checkpoint-evicted mid-generation and restored later
        srv.submit(Request(
            uid=99, tokens=prompts[0][:4], max_new_tokens=2, deadline_s=30.0
        ))
        st = srv.run()
        assert st["preemptions"] >= 1
        assert st["restored_steps_saved"] >= 1
        for i in range(3):
            assert srv.done[i].generated == ref.done[i].generated, i
            assert srv.done[i].token_exit_layers == ref.done[i].token_exit_layers, i
            # same traced shapes on both sides -> the checkpoint round-trip
            # must be BIT-identical, logits included
            np.testing.assert_array_equal(srv.done[i].result, ref.done[i].result)
        assert st["decode_traces"] == 1 and st["prefill_traces"] == 1

    def test_arbiter_clock_survives_decode_checkpoint(self):
        """With the shared-clock arbiter live, a preempted decode lane's
        frozen budget and accumulated layer depth must reconcile at retire
        (no assertion trip), and every request gets a DVFS report."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7), seed=6)
        thr = _probe_threshold(model, params, cfg, prompts)
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        target = no_early_exit_baseline(stats)["latency_s"] * 2.0
        ctrl = LatencyAwareDVFSController(stats, target)
        arb = BatchedDVFSArbiter(ctrl)
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr, preempt=True, arbiter=arb,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        srv.step()
        srv.step()
        srv.submit(Request(
            uid=99, tokens=prompts[0][:4], max_new_tokens=2,
            deadline_s=target * 50,
        ))
        st = srv.run()
        assert st["preemptions"] >= 1
        assert st["accepted_slo_misses"] == 0
        for i in range(3):
            r = srv.done[i]
            assert r.energy_j is not None and r.energy_j > 0
            assert r.latency_s <= arb.now_s
            # arbiter depth reconciled with the realized exit depths
            assert len(r.token_exit_layers) == len(r.generated)


class TestDecodeDVFS:
    def _setup(self):
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7, 4), seed=7)
        thr = _probe_threshold(model, params, cfg, prompts)
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        target = no_early_exit_baseline(stats)["latency_s"] * 2.0
        return model, params, cfg, prompts, thr, stats, target

    def test_exit_enabled_decode_beats_full_depth_energy(self):
        """The acceptance property at test scale: with identical traffic and
        feasible SLOs, exit-enabled decode spends strictly less modeled
        energy than full-depth decode at EQUAL accepted-SLO misses (zero)."""
        model, params, cfg, prompts, thr, stats, target = self._setup()
        energies, misses, avg_exits = {}, {}, {}
        for label, t in (("full", None), ("exit", thr)):
            ctrl = LatencyAwareDVFSController(stats, target)
            srv = DecoderServer(
                model, params, batch_lanes=2, max_seq=32, eos_id=-1,
                buckets=(16,), arbiter=BatchedDVFSArbiter(ctrl),
                exit_threshold=t,
            )
            for i, p in enumerate(prompts):
                srv.submit(Request(
                    uid=i, tokens=p, max_new_tokens=5, deadline_s=target * 10
                ))
            st = srv.run()
            energies[label] = st["energy_j"]
            misses[label] = st["accepted_slo_misses"]
            avg_exits[label] = st["avg_token_exit_layer"]
        assert misses["full"] == misses["exit"] == 0
        assert avg_exits["exit"] < avg_exits["full"] == cfg.n_layers
        assert energies["exit"] < energies["full"]

    def test_cold_calibrator_quotes_full_depth(self):
        """Admission feasibility for a COLD decoder (no tokens observed yet)
        must price the conservative full depth: the service quote equals the
        full-depth token work at the max op plus one switch stall."""
        model, params, cfg, prompts, thr, stats, target = self._setup()
        ctrl = LatencyAwareDVFSController(stats, target)
        arb = BatchedDVFSArbiter(ctrl)
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            arbiter=arb, exit_threshold=thr,
        )
        ac = AdmissionController(srv)
        max_new = 5
        q = ac.quote(Request(
            uid=0, tokens=prompts[0], max_new_tokens=max_new, deadline_s=1.0
        ))
        want = arb.min_latency_quote(float(max_new), srv._cycles_for(16))
        assert q.service_s == pytest.approx(want)
        # and the quote tightens once the calibrator has seen shallow exits
        for pos in range(max_new):
            srv.calib.observe(pos, 1)
        q2 = ac.quote(Request(
            uid=1, tokens=prompts[0], max_new_tokens=max_new, deadline_s=1.0
        ))
        assert q2.service_s < q.service_s

    def test_predict_remaining_steps_uses_position_lut(self):
        """EDF slack consumes the position-binned predictor: fractional
        full-depth steps once the LUT has observations, full token count
        cold."""
        model, params, cfg, prompts, thr, stats, target = self._setup()
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr,
        )
        req = Request(uid=0, tokens=prompts[0], max_new_tokens=4)
        # cold: every remaining token priced at full depth -> 4.0 steps
        assert srv.predict_remaining_steps(16, req, 0) == pytest.approx(4.0)
        for pos in range(4):
            srv.calib.observe(pos, 1)     # everything exits at layer 1
        assert srv.predict_remaining_steps(16, req, 0) == pytest.approx(
            4.0 / cfg.n_layers
        )

    def test_retired_payloads_dropped_after_poll_unless_pinned(self):
        """Decoder-side retention: poll() hands payloads to the caller and
        drops them from done; telemetry keeps counting."""
        model, params, cfg, prompts, thr, stats, target = self._setup()
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=3))
        polled = []
        while srv.step() is not None:
            polled.extend(srv.poll())
        polled.extend(srv.poll())
        assert len(polled) == 4
        assert len(srv.done) == 0            # payloads released
        st = srv.telemetry()
        assert st["completed"] == 4          # accounting survived the drop
        assert st["tokens"] == sum(len(r.generated) for r in polled)
