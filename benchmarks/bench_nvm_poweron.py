"""Paper Fig. 11: energy/latency of reading all embedding weights after
power-on — eNVM-resident (ReRAM) vs conventional DRAM->SRAM."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, trained_albert
from repro.core import bitmask as bm
from repro.hwmodel.edgebert_accel import poweron_embedding_cost


def main() -> None:
    # paper's deployed numbers: 1.73MB compact embedding baseline
    paper = poweron_embedding_cost(1.73e6, 1.73e6 * 0.125)
    emit(
        "fig11_paper_size", paper["envm_latency_s"] * 1e6,
        f"latency_advantage={paper['latency_advantage']:.0f}x (paper ~50x);"
        f"energy_advantage={paper['energy_advantage']:.0f}x (paper ~66000x)",
    )
    # our toy model's actual pruned embedding
    model, params, _, data, cfg = trained_albert()
    enc = bm.encode(np.asarray(params["embed"]["tok"]))
    s = bm.storage_bytes(enc, value_bits=8)
    ours = poweron_embedding_cost(s["value_bytes"], s["mask_bytes"])
    emit(
        "fig11_toy_model", ours["envm_latency_s"] * 1e6,
        f"emb_bytes={s['total_bytes']};latency_advantage={ours['latency_advantage']:.0f}x;"
        f"energy_advantage={ours['energy_advantage']:.0f}x",
    )


if __name__ == "__main__":
    main()
