from repro.common.util import (
    tree_size_bytes,
    tree_num_params,
    human_bytes,
    fold_rng,
    assert_finite,
)
