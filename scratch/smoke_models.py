import jax, jax.numpy as jnp
import numpy as np
import traceback
from dataclasses import replace

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.model import build_model, count_params

rng = jax.random.PRNGKey(0)

for arch in ARCH_IDS + ("albert_base", "albert_edgebert"):
    try:
        cfg = get_smoke_config(arch)
        cfg = replace(cfg, dtype="float32", remat_policy="none")
        m = build_model(cfg)
        params = m.init_params(rng)
        B, S = 2, 64
        batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["enc_input"] = jax.random.normal(rng, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(rng, (B, cfg.n_image_tokens, cfg.d_model)) * 0.1
        out = jax.jit(m.apply_train)(params, batch)
        lg = out.logits if out.logits is not None else out.cls_logits
        assert np.all(np.isfinite(np.asarray(lg))), f"{arch}: NaN in logits"
        print(f"OK  train {arch:24s} params={count_params(params):9d} logits={lg.shape}")
        # decode
        if cfg.family != "albert":
            cache = m.init_cache(B, 128)
            if cfg.family == "encdec":
                lg2, cache = m.prefill(params, batch["tokens"][:, :16], cache, aux={"enc_input": batch["enc_input"]})
            elif cfg.family == "vlm":
                lg2, cache = m.prefill(params, batch["tokens"][:, :16], cache, aux={"image_embeds": batch["image_embeds"]})
            else:
                lg2, cache = m.prefill(params, batch["tokens"][:, :16], cache)
            tok = batch["tokens"][:, :1]
            lg3, cache = jax.jit(m.decode_step, static_argnames=())(params, cache, tok, 16)
            assert np.all(np.isfinite(np.asarray(lg3))), f"{arch}: NaN in decode"
            print(f"OK  decode {arch:22s} logits={lg3.shape}")
    except Exception as e:
        print(f"FAIL {arch}: {e}")
        traceback.print_exc()
