"""Serving engine: the system layer that converts EdgeBERT's per-sentence
early exit into real throughput on batched hardware.

* ``ClassifierServer`` — ALBERT-style classification with entropy early exit.
  Runs the encoder LAYER-BY-LAYER over a batch of lanes; after each layer the
  off-ramp entropy retires finished lanes and REFILLS them from the queue
  (continuation batching).  Unlike the dense masked formulation, lanes never
  idle: average depth/sentence ~ average exit layer, the multi-batch
  generalization of the paper's single-stream latency saving.
* ``DecoderServer`` — LM decode with KV cache, EOS retirement + refill, and
  optional token-level entropy exit (beyond-paper CALM-style adaptation).
* ``MultiTaskRouter`` — the paper's multi-task scenario: one shared (eNVM-
  resident) embedding + per-task encoder/classifier weights; switching tasks
  swaps only task weights, never embeddings (paper §III-D).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import logger
from repro.configs.base import ModelConfig
from repro.core.early_exit import OfframpParams, offramp_logits
from repro.core.entropy import entropy_from_logits
from repro.models.model import Model


@dataclass
class Request:
    uid: int
    tokens: np.ndarray                  # [S] int32
    max_new_tokens: int = 16
    result: Optional[np.ndarray] = None
    exit_layer: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    submit_time: float = 0.0
    finish_time: float = 0.0


# ===========================================================================
# Classifier (early-exit) server
# ===========================================================================


class ClassifierServer:
    def __init__(self, model: Model, params: Any, batch_lanes: int = 8):
        assert model.cfg.family == "albert", "classifier server drives the albert family"
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.cfg = model.cfg
        self.threshold = model.cfg.edgebert.early_exit.entropy_threshold
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._layer_calls = 0       # telemetry: total layer x lane executions
        self._sentences = 0

        lp = self.params["layer"]

        @jax.jit
        def embed_fn(params, tokens):
            return model.embed(params, tokens)

        @jax.jit
        def layer_fn(params, h):
            span_z = model._span_for_layer(params, 0)
            h2, _, _ = model._dense_layer_step(params["layer"], h, causal=False, span_z=span_z)
            return h2

        @jax.jit
        def offramp_fn(params, h):
            lg = offramp_logits(h, model._offramp(params))
            return lg, entropy_from_logits(lg)

        self._embed = embed_fn
        self._layer = layer_fn
        self._offramp = offramp_fn

    def submit(self, req: Request):
        req.submit_time = time.time()
        self.queue.append(req)

    def run(self) -> Dict[str, float]:
        """Drain the queue with continuation batching. Returns telemetry."""
        S = None
        lane_h: List[Optional[jnp.ndarray]] = [None] * self.lanes
        lane_req: List[Optional[Request]] = [None] * self.lanes
        lane_depth = [0] * self.lanes

        def refill():
            for i in range(self.lanes):
                if lane_req[i] is None and self.queue:
                    req = self.queue.popleft()
                    toks = jnp.asarray(req.tokens)[None]
                    lane_h[i] = self._embed(self.params, toks)
                    lane_req[i] = req
                    lane_depth[i] = 0

        refill()
        while any(r is not None for r in lane_req) or self.queue:
            active = [i for i in range(self.lanes) if lane_req[i] is not None]
            if not active:
                refill()
                continue
            h = jnp.concatenate([lane_h[i] for i in active], axis=0)
            h = self._layer(self.params, h)
            self._layer_calls += len(active)
            lg, ent = self._offramp(self.params, h)
            ent = np.asarray(ent)
            lg = np.asarray(lg)
            for j, i in enumerate(active):
                lane_h[i] = h[j : j + 1]
                lane_depth[i] += 1
                req = lane_req[i]
                if ent[j] < self.threshold or lane_depth[i] >= self.cfg.n_layers:
                    req.result = lg[j]
                    req.exit_layer = lane_depth[i]
                    req.finish_time = time.time()
                    self.done[req.uid] = req
                    self._sentences += 1
                    lane_req[i] = None
                    lane_h[i] = None
            refill()

        avg_exit = (
            np.mean([r.exit_layer for r in self.done.values()]) if self.done else 0.0
        )
        return {
            "sentences": self._sentences,
            "layer_calls": self._layer_calls,
            "avg_exit_layer": float(avg_exit),
            "runtime_savings": 1.0 - avg_exit / self.cfg.n_layers,
        }


# ===========================================================================
# Decoder (LM) server
# ===========================================================================


class DecoderServer:
    def __init__(
        self,
        model: Model,
        params: Any,
        batch_lanes: int = 4,
        max_seq: int = 256,
        eos_id: int = 2,
    ):
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}

        @jax.jit
        def decode_fn(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        self._decode = decode_fn

    def submit(self, req: Request):
        req.submit_time = time.time()
        self.queue.append(req)

    def run(self) -> Dict[str, float]:
        """Static-lane continuation batching decode loop."""
        model, params = self.model, self.params
        cache = model.init_cache(self.lanes, self.max_seq)
        lane_req: List[Optional[Request]] = [None] * self.lanes
        lane_pos = np.zeros(self.lanes, np.int32)
        cur_tok = np.zeros((self.lanes, 1), np.int32)
        steps = 0

        def prefill_lane(i, req):
            # prefill via stepwise decode of the prompt (lane-local positions)
            nonlocal cache
            for t, tok in enumerate(req.tokens):
                logits, cache = self._decode(
                    params, cache, jnp.asarray(_one_lane(cur_tok, i, tok)), int(t)
                )
            return logits

        # NOTE: per-lane positions differ; for simplicity this server steps all
        # lanes in lock-step using the max position (correct because K/V for
        # unwritten positions are zero-masked by kv_len bounds per lane is not
        # tracked — acceptable for the CPU demo; the multi-pod serving path
        # uses uniform-length batches from the shape sheet).
        while self.queue or any(r is not None for r in lane_req):
            for i in range(self.lanes):
                if lane_req[i] is None and self.queue:
                    req = self.queue.popleft()
                    lane_req[i] = req
                    # write prompt into lane i step by step
                    for t, tok in enumerate(req.tokens[:-1]):
                        one = np.zeros((self.lanes, 1), np.int32)
                        one[i, 0] = tok
                        _, cache = self._decode(params, cache, jnp.asarray(one), int(t))
                    lane_pos[i] = len(req.tokens) - 1
                    cur_tok[i, 0] = req.tokens[-1]
            active = [i for i in range(self.lanes) if lane_req[i] is not None]
            if not active:
                break
            pos = int(max(lane_pos[i] for i in active))
            logits, cache = self._decode(params, cache, jnp.asarray(cur_tok), pos)
            steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i in active:
                req = lane_req[i]
                tok = int(nxt[i])
                req.generated.append(tok)
                lane_pos[i] = pos + 1
                cur_tok[i, 0] = tok
                if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                    req.finish_time = time.time()
                    self.done[req.uid] = req
                    lane_req[i] = None
            if lane_pos.max() >= self.max_seq - 1:
                for i in active:
                    if lane_req[i] is not None:
                        self.done[lane_req[i].uid] = lane_req[i]
                        lane_req[i] = None
        return {"decode_steps": steps, "completed": len(self.done)}


def _one_lane(cur: np.ndarray, i: int, tok: int) -> np.ndarray:
    out = np.zeros_like(cur)
    out[i, 0] = tok
    return out


# ===========================================================================
# Multi-task router (shared eNVM embeddings)
# ===========================================================================


class MultiTaskRouter:
    """Holds ONE shared embedding table (the eNVM-resident, frozen, pruned
    weights) and per-task encoder/head weights; dispatches requests by task.

    Models the paper's measurement (Fig. 11): task switches swap SRAM-class
    weights only; embedding reload cost is paid once at power-on.
    """

    def __init__(self, model: Model, shared_embed: Any, task_params: Dict[str, Any]):
        self.model = model
        self.shared_embed = shared_embed
        self.tasks: Dict[str, ClassifierServer] = {}
        self.switches = 0
        self.embed_reloads = 1          # power-on load only
        for name, tp in task_params.items():
            params = dict(tp, embed=shared_embed)
            self.tasks[name] = ClassifierServer(model, params)

    def submit(self, task: str, req: Request):
        self.tasks[task].submit(req)

    def run_all(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name, server in self.tasks.items():
            if server.queue:
                self.switches += 1
                out[name] = server.run()
        return out
