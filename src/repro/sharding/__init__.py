from repro.sharding.rules import (
    ShardingRules,
    param_shardings,
    batch_shardings,
    cache_shardings,
    logical_to_mesh,
)
