"""Shared-clock batched DVFS arbitration (single LDO/ADPLL) invariants, the
LDO/ADPLL switching-cost model, and online predictor calibration."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.early_exit import ExitPredictor, OnlineExitCalibrator
from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import (
    ADPLL_RELOCK_S,
    LDO_SETTLE_S_PER_STEP,
    albert_layer_stats,
    op_switch_overhead,
)
from repro.models.model import build_model
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, Request

N_LAYERS = 12


def _stats():
    s = albert_layer_stats(seq_len=64)
    s.n_layers = N_LAYERS
    return s


def _controller(target_mult=1.0, predictor=None, online=None):
    target = no_early_exit_baseline(_stats())["latency_s"] * target_mult
    return LatencyAwareDVFSController(
        _stats(), target, predictor=predictor, online_calibrator=online
    )


def _perfect_predictor(exit_layer: int) -> ExitPredictor:
    return ExitPredictor(
        bin_edges=np.array([]), bin_exit=np.array([float(exit_layer)])
    )


class TestArbiterInvariants:
    def test_chosen_freq_covers_every_lane(self):
        """The shared clock must run at least as fast as EVERY active lane's
        required frequency (the single-LDO feasibility invariant)."""
        c = _controller(2.0, predictor=_perfect_predictor(6))
        arb = BatchedDVFSArbiter(c)
        for lane in range(3):
            arb.admit(lane)
        for step in range(5):
            dec = arb.step([0, 1, 2])
            for lane, need in dec.need_hz.items():
                if math.isfinite(need):
                    assert dec.op.freq_hz >= need - 1e-9, (step, lane, need)
            if step == 0:
                for lane in range(3):
                    arb.observe_entropy(lane, 0.5)

    def test_slowest_sufficient_point_is_chosen(self):
        """Not just feasible: the arbiter picks the SLOWEST table point that
        covers the max requirement (energy minimality per step)."""
        c = _controller(3.0, predictor=_perfect_predictor(4))
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)
        arb.step([0])
        arb.observe_entropy(0, 0.3)
        dec = arb.step([0])
        worst = max(v for v in dec.need_hz.values())
        slower = [p for p in c.table if p.freq_hz < dec.op.freq_hz]
        assert all(p.freq_hz < worst for p in slower)

    def test_first_layer_budget_is_full_depth(self):
        """Before the first off-ramp a lane budgets ALL remaining layers: at
        a slack-free target that forces the nominal point (Alg. 1 line 1)."""
        c = _controller(1.0)
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)
        dec = arb.step([0])
        assert dec.op is c.max_op

    def test_escalation_past_predicted_exit(self):
        """A lane that overruns its prediction requires the max point for
        every subsequent layer (misprediction guard)."""
        c = _controller(2.0, predictor=_perfect_predictor(2))
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)
        arb.step([0])
        arb.observe_entropy(0, 0.5)          # predicted exit = 2
        arb.step([0])                        # layer 2: within prediction
        for _ in range(3):                   # layers 3-5: escalated
            dec = arb.step([0])
            assert math.isinf(dec.need_hz[0])
            assert dec.op is c.max_op
        rep = arb.retire(0, 5)
        assert rep.escalated_layers == 3

    def test_switch_cost_charged_only_on_change(self):
        """Operating-point transitions charge the LDO/ADPLL stall exactly
        when the point CHANGES — steady-state steps are free."""
        c = _controller(2.0, predictor=_perfect_predictor(6))
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)
        arb.step([0])                        # first decision: no prior point
        assert arb.op_switches == 0 and arb.switch_energy_j == 0.0
        arb.observe_entropy(0, 0.5)
        dec2 = arb.step([0])                 # slack -> slower point: 1 switch
        assert dec2.switched and arb.op_switches == 1
        e_after_first = arb.switch_energy_j
        assert e_after_first > 0.0
        dec3 = arb.step([0])                 # same point: no new charge
        if dec3.op == dec2.op:
            assert arb.op_switches == 1
            assert arb.switch_energy_j == e_after_first
        rep = arb.retire(0, 3)
        assert rep.deadline_met

    def test_switch_overhead_model(self):
        ov = op_switch_overhead(0.50, 100e6, 0.80, 500e6, power_mw_nom=100.0)
        # 12 LDO steps of 25mV + one ADPLL relock
        assert ov["time_s"] == pytest.approx(
            12 * LDO_SETTLE_S_PER_STEP + ADPLL_RELOCK_S
        )
        assert ov["energy_j"] > 0
        same = op_switch_overhead(0.6, 250e6, 0.6, 250e6, power_mw_nom=100.0)
        assert same["time_s"] == 0.0 and same["energy_j"] == 0.0

    def test_deadlines_met_with_conservative_predictions(self):
        """Chosen f >= each lane's required f implies every lane with a
        correct-or-conservative prediction retires inside its target."""
        c = _controller(1.5, predictor=_perfect_predictor(8))
        arb = BatchedDVFSArbiter(c)
        reports = arb.replay_batch(
            [[1.0 * 0.8 ** i for i in range(e)] for e in (3, 5, 8, 8)],
            [3, 5, 8, 8],
        )
        assert all(r.deadline_met for r in reports)
        assert all(r.energy_j > 0 for r in reports)

    def test_staggered_admission_separate_deadlines(self):
        """A lane admitted mid-drain gets its own deadline from ITS admission
        time, not the drain start."""
        c = _controller(1.5, predictor=_perfect_predictor(4))
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)
        arb.step([0])
        arb.observe_entropy(0, 0.5)
        t_mid = arb.now_s
        arb.admit(1)                          # staggered admission
        arb.step([0, 1])
        arb.observe_entropy(1, 0.5)
        for _ in range(2):
            arb.step([0, 1])
        r0 = arb.retire(0, 4)
        arb.step([1])
        r1 = arb.retire(1, 4)
        assert r0.deadline_met and r1.deadline_met
        # lane 1's latency is measured from its own admission
        assert r1.latency_s == pytest.approx(arb.now_s - t_mid)


class TestPerRequestDeadlines:
    def test_lane_judged_against_its_own_deadline(self):
        """Two identical lanes, one with a tight per-request deadline, one
        with a loose one: the report's deadline_met must reflect EACH lane's
        OWN budget, not the controller-global target."""
        c = _controller(1.0, predictor=_perfect_predictor(4))
        t_layer = c.layer_time_s(c.max_op)
        arb = BatchedDVFSArbiter(c)
        arb.admit(0, deadline_s=2.5 * t_layer)     # tight: 4 layers won't fit
        arb.admit(1, deadline_s=20.0 * t_layer)    # loose: trivially met
        for step in range(4):
            arb.step([0, 1])
            if step == 0:
                arb.observe_entropy(0, 0.5)
                arb.observe_entropy(1, 0.5)
        r0 = arb.retire(0, 4)
        r1 = arb.retire(1, 4)
        assert r0.target_s == pytest.approx(2.5 * t_layer)
        assert r1.target_s == pytest.approx(20.0 * t_layer)
        assert not r0.deadline_met
        assert r1.deadline_met

    def test_tight_deadline_forces_faster_clock(self):
        """A lane with a tighter deadline requires a higher frequency from
        the shared clock than the same lane at the controller target."""
        c = _controller(3.0, predictor=_perfect_predictor(8))
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)                                   # controller target (3x)
        arb.admit(1, deadline_s=c.target_latency_s / 3.0)  # slack-free
        dec = arb.step([0, 1])
        assert dec.need_hz[1] > dec.need_hz[0]

    def test_default_admit_matches_controller_target(self):
        c = _controller(1.5, predictor=_perfect_predictor(4))
        arb = BatchedDVFSArbiter(c)
        reports = arb.replay_batch([[0.5] * 4], [4])
        assert reports[0].target_s == pytest.approx(c.target_latency_s)

    def test_replay_batch_per_sentence_deadlines(self):
        c = _controller(1.5, predictor=_perfect_predictor(4))
        arb = BatchedDVFSArbiter(c)
        loose = c.target_latency_s * 10
        tight = c.layer_time_s(c.max_op) * 0.5     # < one layer: must miss
        reports = arb.replay_batch(
            [[0.5] * 4, [0.5] * 4], [4, 4], deadlines_s=[loose, tight]
        )
        assert reports[0].deadline_met
        assert not reports[1].deadline_met


class TestPerBucketCycles:
    def test_short_bucket_lane_charged_its_own_cost(self):
        """Two lanes at the max point for 3 layers, one budgeted at the
        16-token bucket's cycles: its energy and required frequency must be
        proportionally smaller than the 64-token lane's."""
        c = _controller(1.0)
        cyc_short = c.cycles_for_seq_len(16)
        assert cyc_short < c.cycles_per_layer      # stats are at seq_len=64
        arb = BatchedDVFSArbiter(c)
        arb.admit(0)                               # default: largest-bucket cost
        arb.admit(1, cycles_per_layer=cyc_short)
        dec = arb.step([0, 1])
        # conservative full-depth budgets scale with the lane's OWN cycles
        assert dec.need_hz[1] == pytest.approx(
            dec.need_hz[0] * cyc_short / c.cycles_per_layer
        )
        for _ in range(2):
            arb.step([0, 1])
        r0 = arb.retire(0, 3)
        r1 = arb.retire(1, 3)
        assert r1.energy_j == pytest.approx(
            r0.energy_j * cyc_short / c.cycles_per_layer
        )

    def test_step_duration_is_stepped_buckets_layer_time(self):
        """A fused step over short-bucket lanes advances the modeled clock by
        the SHORT bucket's layer time, not the largest bucket's."""
        c = _controller(1.0)
        cyc_short = c.cycles_for_seq_len(16)
        arb = BatchedDVFSArbiter(c)
        arb.admit(0, cycles_per_layer=cyc_short)
        dec = arb.step([0])
        assert dec.dt_s == pytest.approx(cyc_short / dec.op.freq_hz)
        arb.retire(0, 1)

    def test_cycle_scaling_is_superlinear_in_seq_len(self):
        """Attention scores scale quadratically, so doubling the bucket must
        more than double the per-layer cycles (and the cache must be
        consistent with a fresh computation)."""
        c = _controller(1.0)
        c16, c32, c64 = (c.cycles_for_seq_len(s) for s in (16, 32, 64))
        assert c64 == pytest.approx(c.cycles_per_layer)   # stats' own length
        assert c32 > 2 * c16 * 0.9 and c64 > 2 * c32 * 0.9
        assert c16 < c32 < c64
        # memoized: same object/value on repeat query
        assert c.cycles_for_seq_len(32) == c32


class TestOnlineCalibration:
    def test_running_quantile_matches_numpy(self):
        cal = OnlineExitCalibrator(12, lo=0.0, hi=1.0, n_bins=4, quantile=1.0)
        rng = np.random.default_rng(0)
        seen = {b: [] for b in range(4)}
        for _ in range(200):
            e = float(rng.uniform(0, 1))
            x = int(rng.integers(1, 13))
            cal.observe(e, x)
            b = int(np.digitize([e], cal.bin_edges)[0])
            seen[b].append(x)
        for b in range(4):
            if seen[b]:
                want = float(np.quantile(seen[b][-256:], 1.0))
                assert cal.bin_exit[b] == pytest.approx(want)

    def test_cold_start_is_conservative_then_adapts(self):
        cal = OnlineExitCalibrator(12, lo=0.0, hi=1.0, n_bins=4)
        assert cal.predict(0.2) == 12.0       # cold start: full depth
        for _ in range(10):
            cal.observe(0.2, 3)
        assert cal.predict(0.2) == 3.0        # adapted to the observed bin
        assert cal.predict(0.9) == 12.0       # unseen bin stays conservative

    def test_controller_predict_prefers_online(self):
        cal = OnlineExitCalibrator(12, lo=0.0, hi=1.0, n_bins=4)
        c = _controller(1.0, predictor=_perfect_predictor(7), online=cal)
        assert c.predict(0.2) == 12.0         # online cold start wins
        c.observe_exit(0.2, 4)
        assert c.predict(0.2) == 4.0

    def test_lut_adapts_during_engine_drain(self):
        """Retired sentences feed the LUT mid-drain: by the end, the online
        calibrator has observations and late sentences of the same entropy
        profile get tighter predictions than the cold start."""
        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticCLS(cfg.vocab_size, 32, 12, num_classes=3, seed=0)
        out = model.apply_train(params, {"tokens": jnp.asarray(data.batch(0)["tokens"])})
        thr = float(np.quantile(np.asarray(out.all_entropies[0]), 0.5))
        cfg = cfg.with_edgebert(
            early_exit=dataclasses.replace(
                cfg.edgebert.early_exit, entropy_threshold=thr
            )
        )
        model = build_model(cfg)
        stats = albert_layer_stats(seq_len=32)
        stats.n_layers = cfg.n_layers
        # median quantile: untrained first entropies cluster into few bins,
        # so bins mix exit-1 and exit-4 sentences — the MEDIAN moves off the
        # cold start even when the windowed max would not
        cal = OnlineExitCalibrator(
            cfg.n_layers, hi=float(np.log(3)) + 0.1, quantile=0.5
        )
        ctrl = LatencyAwareDVFSController(
            stats,
            no_early_exit_baseline(stats)["latency_s"] * 1.5,
            online_calibrator=cal,
        )
        server = ClassifierServer(
            model, params, batch_lanes=3, arbiter=BatchedDVFSArbiter(ctrl)
        )
        for i in range(12):
            server.submit(Request(uid=i, tokens=data.batch(0)["tokens"][i]))
        st = server.run()
        assert st["sentences"] == 12
        assert cal.count == 12                # every retirement was folded in
        # at least one bin moved off the conservative cold-start value
        assert (cal.bin_exit < cfg.n_layers).any()


class TestEngineArbiterIntegration:
    def test_energy_below_max_vf_replay_with_slack(self):
        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3, seed=0)
        out = model.apply_train(params, {"tokens": jnp.asarray(data.batch(0)["tokens"])})
        thr = float(np.quantile(np.asarray(out.all_entropies[0]), 0.3))
        cfg = cfg.with_edgebert(
            early_exit=dataclasses.replace(
                cfg.edgebert.early_exit, entropy_threshold=thr
            )
        )
        model = build_model(cfg)
        from repro.serving.dvfs import calibrate_predictor

        stats = albert_layer_stats(seq_len=32)
        stats.n_layers = cfg.n_layers
        pred = calibrate_predictor(
            model, params, [data.batch(100), data.batch(101)], quantile=1.0
        )
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5, predictor=pred
        )
        arb = BatchedDVFSArbiter(ctrl)
        server = ClassifierServer(model, params, batch_lanes=4, arbiter=arb)
        for i in range(16):
            server.submit(Request(uid=i, tokens=data.batch(0)["tokens"][i]))
        st = server.run()
        exits = [server.done[i].exit_layer for i in range(16)]
        assert len(set(exits)) > 1, "test needs varied exits to be meaningful"
        e_max_replay = sum(exits) * ctrl.layer_energy(ctrl.max_op)
        assert st["arb_energy_j"] < e_max_replay
        assert st["deadline_misses"] == 0
        assert st["arb_energy_j"] == pytest.approx(
            st["energy_j"] + st["switch_energy_j"]
        )

    def test_shared_arbiter_telemetry_is_per_server_delta(self):
        """Two task servers sharing ONE arbiter: each server's telemetry must
        report only ITS drains' arbiter work, and the sum must equal the
        arbiter's drain-global totals (no multi-counting)."""
        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=0)
        stats = albert_layer_stats(seq_len=32)
        stats.n_layers = cfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        arb = BatchedDVFSArbiter(ctrl)
        s1 = ClassifierServer(model, params, batch_lanes=2, arbiter=arb)
        s2 = ClassifierServer(model, params, batch_lanes=2, arbiter=arb)
        for i in range(4):
            s1.submit(Request(uid=i, tokens=data.batch(0)["tokens"][i]))
        st1 = s1.run()
        for i in range(4):
            s2.submit(Request(uid=10 + i, tokens=data.batch(0)["tokens"][4 + i]))
        st2 = s2.run()
        assert st1["arb_energy_j"] > 0 and st2["arb_energy_j"] > 0
        total = arb.telemetry()
        assert st1["arb_energy_j"] + st2["arb_energy_j"] == pytest.approx(
            total["total_energy_j"]
        )
        assert st1["op_switches"] + st2["op_switches"] == total["op_switches"]
        # s2's stats must not include s1's drain
        assert st2["arb_energy_j"] < total["total_energy_j"]

    def test_rejects_both_dvfs_modes(self):
        model_cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
        )
        model = build_model(model_cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        ctrl = _controller(1.0)
        with pytest.raises(AssertionError):
            ClassifierServer(
                model, params, dvfs=ctrl, arbiter=BatchedDVFSArbiter(ctrl)
            )
