"""rwkv6-7b (Finch) [ssm] — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — data-dependent decay time-mix. [arXiv:2404.05892; hf]

Adaptive attention span is INAPPLICABLE (no attention heads; the learned
data-dependent decay w_t is RWKV6's native analogue of a span) — see
DESIGN.md §Arch-applicability.  Runs long_500k (linear in sequence length).
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv heads = d_model / head_size(64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    act="relu2",             # rwkv channel-mix uses squared relu
    norm="layernorm",
    pos="none",
    ssm_state=64,            # per-head state is head_dim x head_dim
    ssm_head_dim=64,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="rwkv6-7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=16,
        max_seq_len=256,
    )
