"""Unified lane scheduler: the lifecycle shared by every serving engine.

``ClassifierServer`` and ``DecoderServer`` used to each own a private copy of
the same loop — submit -> queue -> refill free lanes -> fused step -> retire ->
telemetry.  ``LaneScheduler`` extracts that lifecycle once and drives it
through a small hook interface (``LaneEngine``), so an engine only supplies
the compute: how to materialize a lane bucket, load a request into a lane,
advance all lanes one fused step, and decide per-lane retirement.

Length buckets
--------------
The queue is partitioned by *bucket*: a request is assigned the smallest
configured bucket that fits its shape key (sequence length for the
classifier, prompt + generation budget for the decoder), and its tokens are
padded up to the bucket size by the engine.  Each bucket drains as its own
fixed-shape ``[lanes, S_bucket]`` engine state, so jit compiles EXACTLY ONE
step per bucket instead of one per distinct request length.  ``buckets=None``
keeps the legacy behavior: every distinct shape key is its own bucket.

Telemetry
---------
The scheduler owns the counters every engine used to duplicate: sentences,
fused (dense) steps, active lane-step executions, per-bucket step counts,
refills, and lane occupancy.  Trace counters stay in the engines (they are
incremented inside traced bodies); the scheduler aggregates them per bucket.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional, Protocol, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # circular: engine imports scheduler
    from repro.serving.engine import Request


class LaneEngine(Protocol):
    """Compute hooks a serving engine implements to ride the scheduler.

    The engine owns all device state (hidden tensors, KV caches, jitted
    functions); the scheduler owns queues, lane bookkeeping, and telemetry.
    """

    def bucket_key(self, req: "Request") -> int:
        """Shape key of a request (e.g. sequence length) used for bucketing."""
        ...

    def bucket_begin(self, bucket: int) -> None:
        """Allocate the fixed-shape ``[lanes, bucket]`` state for a drain."""
        ...

    def lane_load(self, bucket: int, lane: int, req: "Request") -> None:
        """Insert a request into a free lane (embed / prefill)."""
        ...

    def lanes_step(self, bucket: int, active: np.ndarray) -> Any:
        """Run ONE fused step over all lanes; returns host-side step outputs."""
        ...

    def lane_advance(
        self, bucket: int, lane: int, req: "Request", out: Any, depth: int
    ) -> bool:
        """Per-lane host postprocess after a step; True retires the lane."""
        ...

    def lane_finish(self, bucket: int, lane: int, req: "Request", depth: int) -> None:
        """Retirement bookkeeping (final logits, DVFS report, ...)."""
        ...

    def bucket_end(self, bucket: int) -> None:
        """Release / park the bucket state after its queue drained."""
        ...


class LaneScheduler:
    """Length-bucketed continuation-batching lane scheduler.

    Parameters
    ----------
    lanes:   number of hardware lanes (the fixed batch dimension).
    engine:  the ``LaneEngine`` hooks supplying compute.
    buckets: ascending bucket sizes (e.g. ``(32, 64, 128)``); a request lands
             in the smallest bucket >= its shape key.  ``None`` = exact-shape
             buckets (one bucket per distinct key — the legacy engines).
    """

    def __init__(self, lanes: int, engine: LaneEngine, buckets=None):
        assert lanes >= 1
        self.lanes = lanes
        self.engine = engine
        self.buckets = tuple(sorted(int(b) for b in buckets)) if buckets else None
        assert self.buckets is None or len(set(self.buckets)) == len(self.buckets)
        self.queues: Dict[int, deque] = {}
        self.done: Dict[int, "Request"] = {}
        # ---- lifetime telemetry (persists across run() calls) ----
        self._sentences = 0
        self._dense_steps = 0
        self._lane_steps = 0            # ACTIVE lane x step executions
        self._refills = 0
        self._bucket_steps: Dict[int, int] = {}

    # ------------------------------------------------------------- queueing
    def bucket_for(self, key: int) -> int:
        if self.buckets is None:
            return int(key)
        for b in self.buckets:
            if key <= b:
                return b
        raise ValueError(
            f"shape key {key} exceeds the largest bucket {self.buckets[-1]}"
        )

    def submit(self, req: "Request") -> int:
        """Queue a request; returns the bucket it landed in."""
        req.submit_time = time.time()
        b = self.bucket_for(self.engine.bucket_key(req))
        self.queues.setdefault(b, deque()).append(req)
        return b

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # --------------------------------------------------------------- drains
    def run(self) -> Dict[str, float]:
        """Drain every non-empty bucket (ascending size); returns telemetry."""
        for b in sorted(self.queues):
            if self.queues[b]:
                self._drain_bucket(b)
        return self.telemetry()

    def _drain_bucket(self, bucket: int) -> None:
        q = self.queues[bucket]
        eng = self.engine
        eng.bucket_begin(bucket)
        lane_req: List[Optional["Request"]] = [None] * self.lanes
        lane_depth = np.zeros(self.lanes, np.int32)
        active = np.zeros(self.lanes, bool)

        while q or active.any():
            # refill every free lane from the bucket queue (continuation
            # batching: retired lanes never idle while work is queued)
            for i in range(self.lanes):
                if lane_req[i] is None and q:
                    req = q.popleft()
                    eng.lane_load(bucket, i, req)
                    lane_req[i] = req
                    lane_depth[i] = 0
                    active[i] = True
                    self._refills += 1
            if not active.any():
                break
            out = eng.lanes_step(bucket, active.copy())
            n_active = int(active.sum())
            self._dense_steps += 1
            self._lane_steps += n_active
            self._bucket_steps[bucket] = self._bucket_steps.get(bucket, 0) + 1
            lane_depth[active] += 1
            for i in range(self.lanes):
                if not active[i]:
                    continue
                req = lane_req[i]
                if eng.lane_advance(bucket, i, req, out, int(lane_depth[i])):
                    eng.lane_finish(bucket, i, req, int(lane_depth[i]))
                    self.done[req.uid] = req
                    self._sentences += 1
                    lane_req[i] = None
                    active[i] = False
        eng.bucket_end(bucket)

    # ------------------------------------------------------------ telemetry
    def telemetry(self) -> Dict[str, float]:
        return {
            "sentences": self._sentences,
            "dense_steps": self._dense_steps,
            "lane_steps": self._lane_steps,
            "refills": self._refills,
            "buckets_used": len(self._bucket_steps),
            "bucket_steps": dict(self._bucket_steps),
            "lane_occupancy": (
                self._lane_steps / (self._dense_steps * self.lanes)
                if self._dense_steps
                else 0.0
            ),
        }
