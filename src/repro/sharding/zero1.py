"""ZeRO-1 optimizer-state sharding: Adam moments get the `data` axis added on
their largest dimension that is (a) not already sharded and (b) divisible —
optimizer memory scales down by the DP degree with zero extra collectives at
update time beyond what XLA already schedules for the (sharded) update.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.training.optim import AdamWState


def zero1_param_sharding(spec: P, shape, mesh: Mesh, dp_axis="data") -> P:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if dp_axis not in axis_sizes:
        return spec
    dp = axis_sizes[dp_axis]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest unsharded, divisible dim
    best, best_dim = -1, -1
    for i, (d, e) in enumerate(zip(shape, entries)):
        if e is None and d % dp == 0 and d > best:
            best, best_dim = d, i
    if best_dim >= 0:
        entries[best_dim] = dp_axis
    return P(*entries)


def zero1_opt_shardings(opt_state: AdamWState, param_shardings: Any, mesh: Mesh) -> AdamWState:
    """NamedSharding tree for AdamWState given the params' sharding tree."""

    def moment(ns: NamedSharding, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, zero1_param_sharding(ns.spec, leaf.shape, mesh))

    m_sh = jax.tree_util.tree_map(moment, param_shardings, opt_state.m)
    v_sh = jax.tree_util.tree_map(moment, param_shardings, opt_state.v)
    return AdamWState(count=NamedSharding(mesh, P()), m=m_sh, v=v_sh)
