"""Serving engine: early-exit classification with lane recycling; LM decode;
multi-task shared-embedding routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS, SyntheticLM
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, DecoderServer, MultiTaskRouter, Request


def _albert_model(threshold=0.6):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(cfg.edgebert.early_exit, entropy_threshold=threshold)
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


class TestClassifierServer:
    def test_results_match_direct_forward(self):
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=0)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=3)
        for i in range(8):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        stats = server.run()
        assert stats["sentences"] == 8
        # compare against the dense all-layers forward for each sentence
        out = model.apply_train(params, {"tokens": jnp.asarray(batch["tokens"])})
        for i in range(8):
            req = server.done[i]
            assert req.exit_layer == int(out.exit_layer[i])
            want = np.asarray(out.all_cls_logits[req.exit_layer - 1, i])
            # lanes run with different batch shapes than the dense pass ->
            # different XLA:CPU vectorization/reassociation; small fp drift
            # compounds through LN+tanh layers. Decisions must agree exactly;
            # logits agree to ~1e-2.
            assert np.argmax(req.result) == np.argmax(want)
            np.testing.assert_allclose(req.result, want, atol=5e-2)

    def test_layer_calls_reflect_early_exit(self):
        """Continuation batching: total layer computations ~ sum(exit layers),
        NOT n_sentences * n_layers — the throughput form of Fig. 4 savings."""
        model, params, cfg = _albert_model(threshold=10.0)  # exit immediately
        data = SyntheticCLS(cfg.vocab_size, 32, 6, num_classes=3, seed=1)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=2)
        for i in range(6):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        stats = server.run()
        assert stats["avg_exit_layer"] == 1.0
        assert stats["layer_calls"] == 6  # one layer per sentence
        assert stats["runtime_savings"] == pytest.approx(1 - 1 / cfg.n_layers)


class TestDecoderServer:
    def test_completes_requests(self):
        cfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(1))
        data = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
        batch = data.batch(0)
        server = DecoderServer(model, params, batch_lanes=2, max_seq=48, eos_id=-1)
        for i in range(3):
            server.submit(Request(uid=i, tokens=batch["tokens"][i][:8], max_new_tokens=4))
        stats = server.run()
        assert stats["completed"] == 3
        assert all(len(server.done[i].generated) == 4 for i in range(3))


class TestMultiTask:
    def test_shared_embeddings_single_copy(self):
        model, params, cfg = _albert_model()
        # two "tasks" share embeddings, differ in encoder/classifier
        p2 = build_model(cfg).init_params(jax.random.PRNGKey(2))
        router = MultiTaskRouter(
            model,
            shared_embed=params["embed"],
            task_params={"mnli": params, "qqp": p2},
        )
        # both servers point at the SAME embedding object (eNVM residency)
        assert router.tasks["mnli"].params["embed"] is router.tasks["qqp"].params["embed"]
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=3)
        b = data.batch(0)
        router.submit("mnli", Request(uid=0, tokens=b["tokens"][0]))
        router.submit("qqp", Request(uid=1, tokens=b["tokens"][1]))
        out = router.run_all()
        assert set(out) == {"mnli", "qqp"}
        assert router.embed_reloads == 1  # never reloaded on switch
