"""Pure-jnp oracles for every Pallas kernel (the `assert_allclose` targets).

These are the semantics contracts: each kernel in this package must match its
oracle bit-for-bit up to float tolerance across the tested shape/dtype sweeps.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptivfloat import AFFormat, af_decode, af_quantize
from repro.core.entropy import entropy_from_logits


# ---------------------------------------------------------------------------
# LayerNorm (paper Eq. 5, E[X^2]-E[X]^2 form)
# ---------------------------------------------------------------------------


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True) - mean * mean
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused masked softmax + entropy (paper Algorithm 1 + Eq. 4)
# ---------------------------------------------------------------------------


def softmax_entropy(
    logits: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row softmax (optionally span-mask-modulated post-softmax, as the GB
    unit does) and the entropy of the *unmasked* distribution."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    z = x - m
    e = jnp.exp(z)
    s = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / s
    ent = jnp.log(s[..., 0]) - jnp.sum(z * e, axis=-1) / s[..., 0]
    if mask is not None:
        probs = probs * mask.astype(jnp.float32)
    return probs.astype(logits.dtype), jnp.maximum(ent, 0.0)


# ---------------------------------------------------------------------------
# AdaptivFloat quantize-dequantize (per-tensor bias)
# ---------------------------------------------------------------------------


def adaptivfloat_quantize(x: jnp.ndarray, fmt: AFFormat = AFFormat()) -> jnp.ndarray:
    return af_quantize(x, fmt)


# ---------------------------------------------------------------------------
# AF8 weight-dequant matmul (paper PU: 8b multiply, 32b accumulate)
# ---------------------------------------------------------------------------


def af_matmul(
    x: jnp.ndarray,            # [M, K] float
    w_codes: jnp.ndarray,      # [K, N] uint8 AF codes
    e_min: jnp.ndarray,        # scalar int32
    fmt: AFFormat = AFFormat(),
) -> jnp.ndarray:
    w = af_decode(w_codes, e_min, fmt, dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Block-sparse matmul (pruned weights; occupancy at tile granularity)
# ---------------------------------------------------------------------------


def block_sparse_matmul(
    x: jnp.ndarray,            # [M, K]
    w: jnp.ndarray,            # [K, N] (already zero outside occupied blocks)
    block_mask: jnp.ndarray,   # [K//bk, N//bn] bool occupancy
    bk: int,
    bn: int,
) -> jnp.ndarray:
    Kb, Nb = block_mask.shape
    mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=0), bn, axis=1)
    w_masked = w * mask[: w.shape[0], : w.shape[1]].astype(w.dtype)
    return (x.astype(jnp.float32) @ w_masked.astype(jnp.float32)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Span-windowed flash attention (hard integer spans, deploy mode)
# ---------------------------------------------------------------------------


def span_attention(
    q: jnp.ndarray,            # [B, H, Sq, dh]
    k: jnp.ndarray,            # [B, KV, Sk, dh]
    v: jnp.ndarray,            # [B, KV, Sk, dh]
    spans: jnp.ndarray,        # [H] int32; 0 = head fully off
    *,
    causal: bool,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, H, Sq, dh = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kk.astype(jnp.float32))
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[2])[None, :]
    d = qi - kj
    if not causal:
        d = jnp.abs(d)
    # within span: 0 <= d < span  (d<0 future keys masked when causal)
    sp = spans[:, None, None].astype(jnp.int32)
    ok = (d[None] < sp) & (d[None] >= 0 if causal else jnp.ones_like(d[None], bool))
    if not causal:
        ok = d[None] < sp
    s = jnp.where(ok[None], s, -jnp.inf)
    # rows with no valid key (span 0) -> zero output
    row_any = jnp.any(ok, axis=-1)  # [H, Sq]
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-20), vv.astype(jnp.float32))
    o = jnp.where(row_any[None, :, :, None], o, 0.0)
    return o.astype(q.dtype)
