"""Fault-tolerant checkpointing: atomic commits, integrity hashes, latest-
pointer, mesh-ELASTIC restore (a checkpoint written on one mesh restores onto
any other — shardings are reapplied at load), preemption hooks.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json  (+ <dir>/LATEST)

Write protocol (crash-safe): write into step_<N>.tmp/, fsync, atomic rename to
step_<N>/, then rewrite LATEST.  A partially-written checkpoint can never be
picked up because LATEST only moves after the rename, and the manifest's
sha256 over the npz guards against torn writes underneath the rename.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import logger

SEP = "||"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat], treedef


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **{k: v for k, v in flat.items()})
    with open(npz_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    manifest = {
        "step": step,
        "sha256": digest,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # move the latest pointer last (atomic via rename)
    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    logger.info("checkpoint saved: %s (%d arrays)", final, len(flat))
    return final


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore_checkpoint(
    directory: str,
    target_tree: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    verify: bool = True,
) -> Tuple[Any, Dict]:
    """Restore into the structure of `target_tree` (mesh-elastic: pass
    `shardings` — a matching pytree of NamedSharding — to place shards for a
    possibly different mesh than the one that wrote the checkpoint)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(path, "arrays.npz")
    if verify:
        with open(npz_path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path} failed integrity check")
    data = np.load(npz_path)

    paths, treedef = _treedef_paths(target_tree)
    missing = [k for k in paths if k not in data]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]} (+{len(missing)-5 if len(missing)>5 else 0})")

    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
    )
    target_leaves = treedef.flatten_up_to(target_tree)
    leaves = []
    for key, tgt, shard in zip(paths, target_leaves, shard_leaves):
        arr = data[key]
        want_dtype = tgt.dtype if hasattr(tgt, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Keeps N checkpoints, auto-resume, preemption-aware saving.

    ``install_preemption_handler()`` hooks SIGTERM: the next ``maybe_save``
    call checkpoints immediately (preempt-save) regardless of cadence — the
    standard behaviour for spot/preemptible fleets.
    """

    def __init__(self, directory: str, save_every: int = 100, keep: int = 3):
        self.directory = directory
        self.save_every = save_every
        self.keep = keep
        self._preempted = threading.Event()

    # ---- preemption ----
    def install_preemption_handler(self):
        def handler(signum, frame):
            logger.warning("SIGTERM received: scheduling preemption checkpoint")
            self._preempted.set()

        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted.is_set()

    def simulate_preemption(self):
        self._preempted.set()

    # ---- save/restore ----
    def maybe_save(self, step: int, tree: Any, extra=None, force: bool = False) -> Optional[str]:
        if force or self.preempted or (step % self.save_every == 0 and step > 0):
            path = save_checkpoint(self.directory, step, tree, extra)
            self._gc()
            self._preempted.clear()
            return path
        return None

    def restore_latest(self, target_tree: Any, shardings=None):
        return restore_checkpoint(self.directory, target_tree, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[-1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
