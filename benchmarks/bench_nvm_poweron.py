"""Paper Fig. 11: energy/latency of reading all embedding weights after
power-on — eNVM-resident (ReRAM) vs conventional DRAM->SRAM — plus the
task-swap cost the residency subsystem charges per non-resident task.

Emits the standard ``name,us,derived`` lines AND appends a versioned
``nvm_poweron`` entry to the BENCH_serving.json history (the same bounded
v2 artifact the serving benchmarks write), so the Fig. 11 reproduction is
tracked across runs instead of scrolling away on stdout.

Usage:
  python benchmarks/bench_nvm_poweron.py            # + trained toy model
  python benchmarks/bench_nvm_poweron.py --smoke    # analytic only, CI-fast
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import append_bench_history, emit, git_tag, trained_albert
from repro.core import bitmask as bm
from repro.hwmodel.edgebert_accel import poweron_embedding_cost
from repro.serving.residency import TaskDeployment


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument(
        "--smoke", action="store_true",
        help="analytic paper-size numbers only (skip the trained toy model)",
    )
    args, _ = parser.parse_known_args()

    # paper's deployed numbers: 1.73MB compact embedding baseline
    paper = poweron_embedding_cost(1.73e6, 1.73e6 * 0.125)
    emit(
        "fig11_paper_size", paper["envm_latency_s"] * 1e6,
        f"latency_advantage={paper['latency_advantage']:.0f}x (paper ~50x);"
        f"energy_advantage={paper['energy_advantage']:.0f}x (paper ~66000x)",
    )
    # the residency subsystem's per-task swap: Fig. 11's read machinery
    # applied to one compressed task's sparse-encoded weight set
    dep = TaskDeployment("paper_task", n_params=11e6, pruning_occupancy=0.4)
    swap = dep.swap_cost()
    emit(
        "nvm_task_swap", swap["latency_s"] * 1e6,
        f"bytes={swap['bytes']:.3e};energy_j={swap['energy_j']:.3e};"
        f"occupancy={dep.pruning_occupancy}",
    )

    entry = {
        "scenario": "nvm_poweron",
        "backend": "analytical",   # modeled eNVM costs, no accelerator in the loop
        "device_count": 1,
        "tag": git_tag(),
        "smoke": bool(args.smoke),
        "paper_size": {
            "envm_latency_s": paper["envm_latency_s"],
            "latency_advantage": paper["latency_advantage"],
            "energy_advantage": paper["energy_advantage"],
        },
        "task_swap": {
            "bytes": swap["bytes"],
            "latency_s": swap["latency_s"],
            "energy_j": swap["energy_j"],
        },
    }

    if not args.smoke:
        # our toy model's actual pruned embedding
        model, params, _, data, cfg = trained_albert()
        enc = bm.encode(np.asarray(params["embed"]["tok"]))
        s = bm.storage_bytes(enc, value_bits=8)
        ours = poweron_embedding_cost(s["value_bytes"], s["mask_bytes"])
        emit(
            "fig11_toy_model", ours["envm_latency_s"] * 1e6,
            f"emb_bytes={s['total_bytes']};latency_advantage={ours['latency_advantage']:.0f}x;"
            f"energy_advantage={ours['energy_advantage']:.0f}x",
        )
        entry["toy_model"] = {
            "emb_bytes": s["total_bytes"],
            "envm_latency_s": ours["envm_latency_s"],
            "latency_advantage": ours["latency_advantage"],
            "energy_advantage": ours["energy_advantage"],
        }

    bench_json = os.path.join(_ROOT, "BENCH_serving.json")
    append_bench_history(bench_json, entry)
    print(f"wrote {os.path.normpath(bench_json)}", flush=True)


if __name__ == "__main__":
    main()
