"""Entropy-based early exit (paper §III-A, Fig. 4; DeeBERT-style off-ramps).

One *shared* highway off-ramp (pooler d x d + classifier d x C — the paper's
0.59 MB figure implies a single shared 768x768 linear) is evaluated after every
encoder block; a sentence exits when H(logits) < T_E.

Execution modes (DESIGN.md §2):
  * ``exit_all_layers``   — dense scan computing every off-ramp's entropy; used
    for training phase 2 and for Fig. 4-style threshold sweeps (one pass gives
    the exit layer for *every* threshold).
  * ``exit_while_loop``   — batch-1 ``lax.while_loop`` with a dynamic trip
    count: layers after the exit are genuinely not executed (the TPU analogue
    of the accelerator's interrupt).
  * ``exit_batched_masked`` — batched serving: per-sample done-mask freezes
    exited rows; the serving engine recycles finished lanes (continuation
    batching) to convert masked rows into real throughput.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entropy import entropy_from_logits


class OfframpParams(NamedTuple):
    pooler_w: jnp.ndarray    # [d, d]
    pooler_b: jnp.ndarray    # [d]
    cls_w: jnp.ndarray       # [d, C]
    cls_b: jnp.ndarray       # [C]


def init_offramp(rng: jax.Array, d_model: int, num_classes: int, dtype=jnp.float32) -> OfframpParams:
    k1, k2 = jax.random.split(rng)
    s1 = 1.0 / jnp.sqrt(d_model)
    return OfframpParams(
        pooler_w=(jax.random.normal(k1, (d_model, d_model)) * s1).astype(dtype),
        pooler_b=jnp.zeros((d_model,), dtype),
        cls_w=(jax.random.normal(k2, (d_model, num_classes)) * s1).astype(dtype),
        cls_b=jnp.zeros((num_classes,), dtype),
    )


def offramp_logits(h: jnp.ndarray, p: OfframpParams) -> jnp.ndarray:
    """h: [..., seq, d] -> logits [..., C].  CLS pooling (token 0) + tanh."""
    cls = h[..., 0, :]
    pooled = jnp.tanh(cls @ p.pooler_w + p.pooler_b)
    return pooled @ p.cls_w + p.cls_b


# ---------------------------------------------------------------------------
# Mode 1: dense all-layers (training / Fig. 4 sweeps)
# ---------------------------------------------------------------------------


def exit_all_layers(
    layer_fn: Callable[[int, jnp.ndarray], jnp.ndarray],
    n_layers: int,
    h0: jnp.ndarray,
    offramp: OfframpParams,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run every layer; return (all_logits [L, B, C], all_entropy [L, B])."""

    def body(h, i):
        h = layer_fn(i, h)
        lg = offramp_logits(h, offramp)
        return h, (lg, entropy_from_logits(lg))

    _, (logits, ent) = jax.lax.scan(body, h0, jnp.arange(n_layers))
    return logits, ent


def exit_decisions(entropies: jnp.ndarray, threshold: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Given per-layer entropies [L, B], the exit layer per sample (1-based)
    and a mask of which (layer, sample) produced the final prediction."""
    L = entropies.shape[0]
    below = entropies < threshold
    # force exit at the last layer
    below = below.at[-1].set(True)
    exit_layer = jnp.argmax(below, axis=0)  # first True
    onehot = jax.nn.one_hot(exit_layer, L, axis=0, dtype=entropies.dtype)
    return exit_layer + 1, onehot


def select_exit_logits(all_logits: jnp.ndarray, exit_layer_1based: jnp.ndarray) -> jnp.ndarray:
    """all_logits [L, B, C], exit_layer [B] -> [B, C]."""
    return jnp.take_along_axis(
        all_logits, (exit_layer_1based - 1)[None, :, None], axis=0
    )[0]


# ---------------------------------------------------------------------------
# Mode 2: batch-1 while_loop (true dynamic depth)
# ---------------------------------------------------------------------------


def exit_while_loop(
    layer_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    n_layers: int,
    h0: jnp.ndarray,
    offramp: OfframpParams,
    threshold: float,
):
    """h0: [seq, d] (single sentence). layer_fn(layer_idx, h) -> h.

    Returns (logits [C], exit_layer (1-based), entropy_at_exit).
    Layers beyond the exit are *not executed* — dynamic trip count.
    """
    C = offramp.cls_b.shape[0]

    def cond(state):
        i, h, done, logits, ent = state
        return jnp.logical_and(i < n_layers, jnp.logical_not(done))

    def body(state):
        i, h, done, logits, ent = state
        h = layer_fn(i, h)
        lg = offramp_logits(h[None], offramp)[0]
        e = entropy_from_logits(lg)
        exit_now = jnp.logical_or(e < threshold, i == n_layers - 1)
        return (i + 1, h, exit_now, lg, e)

    init = (
        jnp.array(0, jnp.int32),
        h0,
        jnp.array(False),
        jnp.zeros((C,), jnp.float32),
        jnp.array(jnp.inf, jnp.float32),
    )
    i, h, done, logits, ent = jax.lax.while_loop(cond, body, init)
    return logits, i, ent


# ---------------------------------------------------------------------------
# Mode 3: batched masked (serving)
# ---------------------------------------------------------------------------


def exit_batched_masked(
    layer_fn: Callable[[int, jnp.ndarray], jnp.ndarray],
    n_layers: int,
    h0: jnp.ndarray,            # [B, S, D]
    offramp: OfframpParams,
    threshold: float,
):
    """Per-sample freeze-on-exit. Returns (logits [B, C], exit_layer [B]).

    FLOPs are dense here; the serving engine converts the done-mask into
    throughput by recycling exited lanes between blocks.
    """

    def body(carry, i):
        h, done, logits, exit_layer = carry
        h_new = layer_fn(i, h)
        h = jnp.where(done[:, None, None], h, h_new)
        lg = offramp_logits(h, offramp)
        ent = entropy_from_logits(lg)
        exit_now = jnp.logical_and(jnp.logical_not(done), ent < threshold)
        last = i == n_layers - 1
        take = jnp.logical_or(exit_now, jnp.logical_and(last, jnp.logical_not(done)))
        logits = jnp.where(take[:, None], lg, logits)
        exit_layer = jnp.where(take, i + 1, exit_layer)
        done = jnp.logical_or(done, exit_now)
        return (h, done, logits, exit_layer), None

    B = h0.shape[0]
    C = offramp.cls_b.shape[0]
    init = (
        h0,
        jnp.zeros((B,), bool),
        jnp.zeros((B, C), jnp.float32),
        jnp.zeros((B,), jnp.int32),
    )
    (h, done, logits, exit_layer), _ = jax.lax.scan(body, init, jnp.arange(n_layers))
    return logits, exit_layer


# ---------------------------------------------------------------------------
# Exit-layer prediction (paper Alg. 1: LUT trained offline, indexed by the
# first off-ramp's entropy — the signal driving sentence-level DVFS)
# ---------------------------------------------------------------------------


class ExitPredictor(NamedTuple):
    """Binned LUT: first-off-ramp entropy -> expected total exit layer.

    Mirrors the ASIC's small SRAM lookup table: ``bin_edges`` are the
    programmable comparator thresholds, ``bin_exit`` the stored predictions.
    """

    bin_edges: np.ndarray    # [n_bins - 1] interior entropy bin edges
    bin_exit: np.ndarray     # [n_bins] expected exit layer (1-based, float)


def fit_exit_predictor(
    first_layer_entropy: np.ndarray,
    exit_layers: np.ndarray,
    n_bins: int = 16,
    quantile: Optional[float] = None,
) -> ExitPredictor:
    """Calibrate the LUT from a profiling run (dense all-layers forward).

    ``quantile=None`` stores each bin's MEAN exit layer (minimum expected
    energy); a quantile (e.g. 0.9 or 1.0) stores that quantile instead —
    conservative prediction that trades energy for fewer latency-target
    violations when a sentence runs deeper than its bin's average (the DVFS
    controller escalates to max V/f past the predicted layer, which cannot
    recapture time already spent at a slow operating point).

    Empty bins are filled by interpolation between their filled neighbours so
    ``predict_exit_layer`` is total over the observed entropy range.
    """
    e = np.asarray(first_layer_entropy, np.float64).ravel()
    x = np.asarray(exit_layers, np.float64).ravel()
    assert e.shape == x.shape and e.size > 0
    lo, hi = float(e.min()), float(e.max())
    if hi <= lo:
        hi = lo + 1e-6
    edges = np.linspace(lo, hi, n_bins + 1)[1:-1]
    idx = np.digitize(e, edges)
    mean = np.full(n_bins, np.nan)
    for b in range(n_bins):
        sel = idx == b
        if sel.any():
            mean[b] = (
                x[sel].mean() if quantile is None else np.quantile(x[sel], quantile)
            )
    filled = ~np.isnan(mean)
    centers = np.arange(n_bins, dtype=np.float64)
    mean = np.interp(centers, centers[filled], mean[filled])
    return ExitPredictor(bin_edges=edges, bin_exit=mean)


def predict_exit_layer(predictor: ExitPredictor, entropy: float) -> float:
    """Expected total exit layer (1-based) for a sentence whose FIRST
    off-ramp entropy is ``entropy``."""
    b = int(np.digitize([float(entropy)], predictor.bin_edges)[0])
    return float(predictor.bin_exit[b])


class OnlineExitCalibrator:
    """Streaming replacement for the offline ``calibrate_predictor`` pass.

    Keeps a bounded window of (first-off-ramp entropy, exit layer) pairs per
    entropy bin and re-estimates each bin's exit-layer *quantile* on every
    observation, so the LUT adapts DURING a drain instead of requiring a
    profiling pass up front.  Bins with no observations yet predict the full
    ``n_layers`` — the conservative cold-start (never misses a deadline,
    saves no energy) that the running quantiles then tighten.

    ``quantile=1.0`` tracks each bin's windowed max (safest for slack-free
    latency targets); lower quantiles trade occasional escalation for energy,
    exactly like the offline ``fit_exit_predictor`` knob.
    """

    def __init__(
        self,
        n_layers: int,
        *,
        lo: float = 0.0,
        hi: float = 1.1,
        n_bins: int = 16,
        quantile: float = 1.0,
        window: int = 256,
    ):
        assert hi > lo and n_bins >= 1 and window >= 1
        assert 0.0 <= quantile <= 1.0
        self.n_layers = int(n_layers)
        self.quantile = float(quantile)
        self.bin_edges = np.linspace(lo, hi, n_bins + 1)[1:-1]
        self._windows = [deque(maxlen=window) for _ in range(n_bins)]
        self.bin_exit = np.full(n_bins, float(n_layers))
        self.count = 0

    def observe(self, first_entropy: float, exit_layer: int) -> None:
        """Fold one retired sentence into its bin's running quantile."""
        b = int(np.digitize([float(first_entropy)], self.bin_edges)[0])
        w = self._windows[b]
        w.append(float(np.clip(exit_layer, 1, self.n_layers)))
        self.bin_exit[b] = float(np.quantile(np.asarray(w), self.quantile))
        self.count += 1

    def predict(self, first_entropy: float) -> float:
        b = int(np.digitize([float(first_entropy)], self.bin_edges)[0])
        return float(self.bin_exit[b])

    def predictor(self) -> ExitPredictor:
        """Snapshot as an ``ExitPredictor`` LUT (the ASIC's SRAM table image)."""
        return ExitPredictor(
            bin_edges=self.bin_edges.copy(), bin_exit=self.bin_exit.copy()
        )


class PositionBinnedExitCalibrator(OnlineExitCalibrator):
    """Token-level variant of the online LUT: keyed by DECODE POSITION bin.

    The classifier's Alg. 1 predictor maps a sentence's first-off-ramp
    entropy to its exit layer.  Autoregressive decode has no single "first
    off-ramp" per request — every generated token takes its own off-ramp
    walk — but token exit depth correlates strongly with the token's
    POSITION in the generation (early tokens copy prompt structure and exit
    shallow; later tokens carry more uncertainty), so the decode-side LUT
    bins on position instead: ``observe(position, exit_layer)`` folds a
    generated token into its position bin's running quantile and
    ``predict(position)`` reads it back.  Machinery (bounded windows,
    per-bin quantiles, conservative full-depth cold start) is inherited
    unchanged from ``OnlineExitCalibrator`` — position is just a different
    scalar key into the same SRAM-table image.
    """

    def __init__(
        self,
        n_layers: int,
        *,
        max_pos: int = 256,
        n_bins: int = 8,
        quantile: float = 1.0,
        window: int = 256,
    ):
        assert max_pos >= 1
        super().__init__(
            n_layers, lo=0.0, hi=float(max_pos), n_bins=n_bins,
            quantile=quantile, window=window,
        )

    def predict_range(self, pos_start: int, pos_end: int) -> float:
        """Vectorized ``predicted_token_layers`` over [pos_start, pos_end):
        one digitize over the position range instead of a per-token Python
        loop — the serving engine refreshes every active lane's remainder
        each fused step, so this is hot-path."""
        if pos_end <= pos_start:
            return 0.0
        idx = np.digitize(np.arange(pos_start, pos_end, dtype=np.float64),
                          self.bin_edges)
        return float(np.clip(self.bin_exit[idx], 1.0, self.n_layers).sum())

    def bin_fill_counts(self) -> np.ndarray:
        """Observations currently held per position bin — the speculative
        decode regression signal: a server that folds one depth per accepted
        BLOCK (instead of one per accepted TOKEN) starves the bins covering
        positions inside accepted prefixes, visible here as empty windows."""
        return np.array([len(w) for w in self._windows], dtype=np.int64)


class ExitThresholdSchedule:
    """Per-position / per-entropy-band generalization of the scalar exit
    threshold (the knob ``decode_step_ee`` compares off-ramp entropy to).

    The scalar threshold treats every decode position identically, but token
    confidence is strongly position-dependent (the same structure the
    ``PositionBinnedExitCalibrator`` exploits for depth prediction): early
    continuation tokens copy prompt structure and can afford a LOOSER
    threshold (exit more, draft more under speculation), while
    high-uncertainty stretches warrant a tighter one.  The schedule is a
    piecewise-constant multiplier surface over (position bin, entropy band)
    applied to a ``base`` threshold:

      * ``position_edges`` / ``position_scales`` — multiplier by decode
        position (``len(scales) == len(edges) + 1``, digitize semantics);
      * ``band_edges`` / ``band_scales`` — multiplier by the lane's LAST
        observed first-off-ramp entropy (a cheap per-lane confidence proxy:
        a lane that just read a confident ramp speculates harder);
      * a ``PositionBinnedExitCalibrator`` may back the schedule: ``observe``
        forwards every accepted token's realized depth into the calibrator
        (the one prediction chain stays shared), and ``from_calibrator``
        derives position scales from the warmed bins.

    With no edges the schedule is CONSTANT and ``threshold_at(p) == base``
    exactly, so the degenerate schedule is bit-identical to the scalar
    threshold — the parity anchor the speculative-decode tests pin.
    """

    def __init__(
        self,
        base: float,
        *,
        position_edges=(),
        position_scales=(1.0,),
        band_edges=(),
        band_scales=(1.0,),
        calibrator: Optional["PositionBinnedExitCalibrator"] = None,
        min_threshold: float = 0.0,
        max_threshold: Optional[float] = None,
    ):
        self.base = float(base)
        self.position_edges = np.asarray(position_edges, np.float64)
        self.position_scales = np.asarray(position_scales, np.float64)
        self.band_edges = np.asarray(band_edges, np.float64)
        self.band_scales = np.asarray(band_scales, np.float64)
        assert self.position_scales.size == self.position_edges.size + 1, (
            "need len(position_scales) == len(position_edges) + 1"
        )
        assert self.band_scales.size == self.band_edges.size + 1, (
            "need len(band_scales) == len(band_edges) + 1"
        )
        self.calibrator = calibrator
        self.min_threshold = float(min_threshold)
        self.max_threshold = max_threshold

    @classmethod
    def from_calibrator(
        cls,
        base: float,
        calibrator: "PositionBinnedExitCalibrator",
        *,
        loosen: float = 1.25,
        tighten: float = 0.85,
        **kwargs,
    ) -> "ExitThresholdSchedule":
        """Derive position scales from a (partially) warmed calibrator: bins
        whose running quantile predicts a SHALLOW exit (< half depth) are
        confident regions and loosen the threshold; bins predicting deep
        exits tighten it; cold bins (still at the conservative full depth)
        keep the base — a cold calibrator yields the constant schedule."""
        n_layers = float(calibrator.n_layers)
        scales = []
        for pred in calibrator.bin_exit:
            if pred >= n_layers - 1e-9:          # cold or genuinely full-depth
                scales.append(1.0)
            elif pred <= n_layers / 2.0:
                scales.append(float(loosen))
            else:
                scales.append(float(tighten))
        return cls(
            base,
            position_edges=calibrator.bin_edges.copy(),
            position_scales=np.asarray(scales),
            calibrator=calibrator,
            **kwargs,
        )

    def _clip(self, t: np.ndarray) -> np.ndarray:
        hi = np.inf if self.max_threshold is None else self.max_threshold
        return np.clip(t, self.min_threshold, hi)

    def thresholds(
        self, pos_start: int, count: int, last_entropy: Optional[float] = None
    ) -> np.ndarray:
        """Vectorized thresholds for positions [pos_start, pos_start+count)
        — the per-slot threshold row a speculative fused step consumes
        (slot j speculates the token at position ``pos_start + j``)."""
        positions = np.arange(pos_start, pos_start + count, dtype=np.float64)
        if self.position_edges.size:
            scale = self.position_scales[
                np.digitize(positions, self.position_edges)
            ]
        else:
            scale = np.full(count, self.position_scales[0])
        if self.band_edges.size and last_entropy is not None:
            b = int(np.digitize([float(last_entropy)], self.band_edges)[0])
            scale = scale * self.band_scales[b]
        return self._clip(self.base * scale).astype(np.float32)

    def threshold_at(
        self, position: int, last_entropy: Optional[float] = None
    ) -> float:
        return float(self.thresholds(position, 1, last_entropy)[0])

    def observe(
        self, position: int, first_entropy: float, exit_layer: int
    ) -> None:
        """Fold one ACCEPTED token's realized depth into the backing
        calibrator (every accepted token, not one per block — the bin-fill
        regression the speculative tests pin)."""
        if self.calibrator is not None:
            self.calibrator.observe(position, exit_layer)


def predicted_token_layers(
    predict_fn: Callable[[int], float],
    pos_start: int,
    pos_end: int,
    n_layers: int,
) -> float:
    """Predicted TOTAL layers for the tokens at positions [pos_start, pos_end).

    ``predict_fn`` is a per-position exit-depth predictor (e.g.
    ``PositionBinnedExitCalibrator.predict``); each position's prediction is
    clamped to ``[1, n_layers]`` so a cold calibrator quotes the conservative
    full depth for every remaining token.  This is the decode-side analogue
    of ``predicted_remaining_layers``: the scheduler's EDF slack, the DVFS
    arbiter's required frequency, and the admission feasibility quote all
    consume it, so the three layers budget decode work off ONE prediction
    chain.
    """
    if pos_end <= pos_start:
        return 0.0
    total = 0.0
    for t in range(int(pos_start), int(pos_end)):
        total += float(np.clip(predict_fn(t), 1.0, n_layers))
    return total


def predicted_remaining_layers(
    entropy_trace,
    depth: int,
    n_layers: int,
    *,
    predict_fn: Optional[Callable[[float], float]] = None,
) -> float:
    """Remaining encoder layers a sentence is predicted to need.

    The scheduler's EDF policy ranks buckets by slack = deadline - now -
    (this value x the bucket's step time).  ``predict_fn`` maps a first
    off-ramp entropy to a predicted total exit layer — callers pass the ONE
    prediction chain they already own (e.g.
    ``LatencyAwareDVFSController.predict``, which prefers the online
    calibrator over the static LUT), so the EDF slack estimate cannot drift
    from the DVFS frequency decision.  Before the first off-ramp (empty
    ``entropy_trace``) or without a ``predict_fn`` the prediction is the
    conservative full depth.  A sentence that has RUN PAST its predicted
    exit is a misprediction: its true exit is unknown, so the remainder
    reverts to the conservative full depth (mirroring the DVFS escalation
    guard — an optimistic remainder here would let EDF defer the lane until
    its deadline is unrecoverable).  Clamped to >= 1: there is always at
    least the step that retires it.
    """
    if len(entropy_trace) == 0 or predict_fn is None:
        p = float(n_layers)
    else:
        p = float(predict_fn(float(entropy_trace[0])))
    p = float(np.clip(p, 1.0, n_layers))
    if depth >= p - 1e-9:                 # overran the prediction: escalate
        return max(float(n_layers) - depth, 1.0)
    return max(p - depth, 1.0)


def runtime_savings(exit_layers: jnp.ndarray, n_layers: int) -> jnp.ndarray:
    """Paper's 'theoretical runtime savings' = 1 - avg_exit/L (Fig. 4)."""
    return 1.0 - jnp.mean(exit_layers.astype(jnp.float32)) / n_layers


def ee_perf(accuracy: float, savings: float) -> float:
    """Paper Eq. 2: EE_perf = accuracy / (1 - savings)."""
    return accuracy / max(1.0 - savings, 1e-9)
