"""Fault tolerance demo: train, get preempted mid-run, resume exactly —
then restore the same checkpoint under a different precision (mesh-elastic
restore recasts/re-shards on load).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import dataclasses
import sys, os, tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train_loop import make_train_step

cfg = dataclasses.replace(
    get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
)
model = build_model(cfg)
data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=40)))

ckpt_dir = tempfile.mkdtemp(prefix="edgebert_ckpt_")
mgr = CheckpointManager(ckpt_dir, save_every=10)

params = model.init_params(jax.random.PRNGKey(0))
opt_state = adamw_init(params)

print("== run 1: train until 'preemption' at step 25 ==")
for step in range(40):
    batch = {"tokens": jnp.asarray(data.batch(step)["tokens"])}
    params, opt_state, m = step_fn(params, opt_state, batch)
    mgr.maybe_save(step, {"params": params, "opt": opt_state})
    if step == 25:
        mgr.simulate_preemption()          # SIGTERM from the scheduler
        mgr.maybe_save(step, {"params": params, "opt": opt_state})
        print(f"   preempted at step {step}, loss={float(m['loss']):.4f}")
        break

print("== run 2: fresh process resumes from LATEST ==")
params2 = model.init_params(jax.random.PRNGKey(0))
state, manifest = mgr.restore_latest({"params": params2, "opt": adamw_init(params2)})
params2, opt2 = state["params"], state["opt"]
resume_step = manifest["step"]
print(f"   resumed at step {resume_step}")
for step in range(resume_step + 1, 40):
    # data is a pure function of (seed, step): restart-exact
    batch = {"tokens": jnp.asarray(data.batch(step)["tokens"])}
    params2, opt2, m = step_fn(params2, opt2, batch)
print(f"   finished at step 39, loss={float(m['loss']):.4f}")

print("== elastic restore: same checkpoint into a bf16 replica ==")
cfg_bf16 = dataclasses.replace(cfg, dtype="bfloat16")
model_bf16 = build_model(cfg_bf16)
target = model_bf16.init_params(jax.random.PRNGKey(0))
state_bf16, _ = mgr.restore_latest({"params": target, "opt": adamw_init(target)})
print(f"   restored wq dtype: {state_bf16['params']['layers']['attn']['wq'].dtype} "
      "(recast on load; shardings would be reapplied the same way on a new mesh)")
