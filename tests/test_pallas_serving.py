"""Serving-level parity gates for the Pallas-fused step (``use_pallas=``).

Each test drains the SAME request mix through two otherwise-identical
servers — ``use_pallas=False`` (reference jnp math) and ``use_pallas=True``
(Pallas kernels via ``serving.step_math`` / ``kernels.dispatch``, interpret
mode on CPU) — and gates:

  * logits within fp tolerance (kernels accumulate in f32; the only drift
    source is reduction order),
  * exit depths EXACTLY equal (entropy-vs-threshold decisions must not flip
    across the dispatch boundary — a flipped exit changes latency, energy,
    and the DVFS replay, not just a few ulps),
  * telemetry trace counts EQUAL with ``step_traces <= bucket count`` (the
    flag is static: routing to Pallas must add zero compiles),
  * the checkpoint/preempt/restore cycle round-trips through the Pallas
    step bit-identically to an uninterrupted Pallas run.

The smoke albert_edgebert config keeps adaptive span ENABLED, so its
serving attention stays on the reference path (a soft ramped span mask has
no hard-window kernel equivalent) while layernorm, off-ramp entropy, and
activation quant route to Pallas.  The span-DISABLED variant below is what
drives ``dispatch.dense_attention`` (the span kernel at full window with
per-lane kv_len) in serving — asserted via a call counter so the kernel
path can't silently stop firing.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, DecoderServer, Request

ATOL = 2e-4          # f32 logits, reduction-order drift only


def _albert_model(threshold=1.06, span=True):
    # default threshold sits mid-distribution of the random-init first
    # off-ramp entropies (probed: ~1.03..1.08) so the drain mixes early
    # exits with full-depth lanes — exit-depth parity must not be vacuous
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        ),
        span=dataclasses.replace(cfg.edgebert.span, enabled=span),
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _decoder_model():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return model, params, cfg


def _cls_requests(cfg, n=8):
    batch = SyntheticCLS(cfg.vocab_size, 32, n, num_classes=3, seed=0).batch(0)
    lengths = [12, 16, 9, 24, 32, 16, 27, 12]
    return [
        Request(uid=i, tokens=batch["tokens"][i][: lengths[i % len(lengths)]])
        for i in range(n)
    ]


def _drain_pair(model, params, n_requests_cfg, **server_kw):
    """Run the same mix through ref and Pallas servers; return both."""
    servers = {}
    for use_pallas in (False, True):
        srv = ClassifierServer(model, params, use_pallas=use_pallas, **server_kw)
        for r in _cls_requests(n_requests_cfg):
            srv.submit(dataclasses.replace(r))
        srv.run()
        servers[use_pallas] = srv
    return servers[False], servers[True]


class TestClassifierParity:
    def test_bucketed_drain_logits_exits_traces(self):
        model, params, cfg = _albert_model()
        ref, pal = _drain_pair(
            model, params, cfg, batch_lanes=4, buckets=(16, 32)
        )
        n = len(ref.done)
        assert n == len(pal.done) == 8
        for i in range(n):
            assert pal.done[i].exit_layer == ref.done[i].exit_layer, i
            np.testing.assert_allclose(
                pal.done[i].result, ref.done[i].result, atol=ATOL
            )
        # the threshold must actually split the mix, or exit parity is vacuous
        depths = {ref.done[i].exit_layer for i in range(n)}
        assert any(d < cfg.n_layers for d in depths)
        # zero additional traces from the Pallas routing; one per bucket
        t_ref, t_pal = ref.telemetry(), pal.telemetry()
        assert t_pal["step_traces"] == t_ref["step_traces"]
        assert t_pal["step_traces"] <= 2      # <= bucket count
        assert t_pal["embed_traces"] == t_ref["embed_traces"]
        assert t_pal["insert_traces"] == t_ref["insert_traces"]

    def test_span_disabled_variant_fires_span_kernel(self):
        """Without learned spans serving attention routes to the Pallas span
        kernel (full window, per-lane kv_len); parity must hold AND the
        kernel must demonstrably fire."""
        model, params, cfg = _albert_model(span=False)
        assert "span_z" not in params          # precondition for the route

        from repro.kernels import dispatch

        calls = {"n": 0}
        orig = dispatch.dense_attention

        def counting(*a, **kw):
            calls["n"] += 1
            return orig(*a, **kw)

        dispatch.dense_attention = counting
        try:
            ref, pal = _drain_pair(
                model, params, cfg, batch_lanes=2, buckets=(16, 32)
            )
        finally:
            dispatch.dense_attention = orig
        assert calls["n"] >= 1                 # traced at least once
        for i in range(len(ref.done)):
            assert pal.done[i].exit_layer == ref.done[i].exit_layer, i
            np.testing.assert_allclose(
                pal.done[i].result, ref.done[i].result, atol=ATOL
            )

    def test_preempt_restore_roundtrip_under_pallas(self):
        """Checkpoint/preempt/restore through the Pallas step: identical
        results and exit depths vs an uninterrupted Pallas run, zero extra
        traces (restore reuses the bucket's compiled insert)."""
        model, params, cfg = _albert_model(threshold=1e-9)
        batch = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3,
                             seed=0).batch(0)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,),
                               preempt=True, use_pallas=True)
        ref = ClassifierServer(model, params, batch_lanes=2, buckets=(16,),
                               use_pallas=True)
        for s in (srv, ref):
            for i in range(3):
                s.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        srv.step()
        srv.step()
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][4][:12],
            deadline_s=float(cfg.n_layers + 3),
        ))
        while srv.step() is not None:
            pass
        while ref.step() is not None:
            pass
        st, st_ref = srv.telemetry(), ref.telemetry()
        assert st["preemptions"] >= 1
        assert any(srv.done[i].preempted for i in range(3))
        for i in range(3):
            assert srv.done[i].exit_layer == ref.done[i].exit_layer, i
            assert np.array_equal(srv.done[i].result, ref.done[i].result), i
        assert st["step_traces"] == st_ref["step_traces"] == 1
        assert st["insert_traces"] == st_ref["insert_traces"] == 1


class TestDecoderParity:
    def test_early_exit_drain_tokens_and_depths(self):
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(0)
        prompts = [rng.integers(3, cfg.vocab_size, s).astype(np.int32)
                   for s in (4, 6, 5, 7)]
        servers = {}
        for use_pallas in (False, True):
            srv = DecoderServer(model, params, batch_lanes=2, max_seq=32,
                                # probed median of the random-init per-token
                                # first-off-ramp entropies (~6.224..6.227)
                                buckets=(16,), exit_threshold=6.2255,
                                use_pallas=use_pallas)
            for i, p in enumerate(prompts):
                srv.submit(Request(uid=i, tokens=p, max_new_tokens=6))
            srv.run()
            servers[use_pallas] = srv
        ref, pal = servers[False], servers[True]
        for i in range(len(prompts)):
            assert pal.done[i].generated == ref.done[i].generated, i
            assert pal.done[i].token_exit_layers == ref.done[i].token_exit_layers, i
        # the EE threshold must bite somewhere or depth parity is vacuous
        depths = [d for i in range(len(prompts))
                  for d in ref.done[i].token_exit_layers]
        assert any(d < cfg.n_layers for d in depths)
        t_ref, t_pal = ref.telemetry(), pal.telemetry()
        assert t_pal["decode_traces"] == t_ref["decode_traces"] == 1
        assert t_pal["prefill_traces"] == t_ref["prefill_traces"]

    def test_full_depth_drain_matches_ref(self):
        """No early exit (decode_fn path): generated tokens exactly equal."""
        model, params, cfg = _decoder_model()
        prompt = np.arange(2, 7, dtype=np.int32)
        outs = {}
        for use_pallas in (False, True):
            srv = DecoderServer(model, params, batch_lanes=2, max_seq=32,
                                buckets=(16,), use_pallas=use_pallas)
            srv.submit(Request(uid=0, tokens=prompt, max_new_tokens=6))
            srv.run()
            outs[use_pallas] = srv.done[0].generated
        assert outs[True] == outs[False]
