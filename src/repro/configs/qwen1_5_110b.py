"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.

QKV bias per Qwen1.5 family. [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=1000000.0,
    qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="qwen1.5-110b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab_size=512,
        max_seq_len=256,
    )
