"""Shared-clock batched DVFS arbitration vs per-sentence replay.

The EdgeBERT accelerator has ONE LDO/ADPLL pair, so a batched deployment
cannot give every sentence its own (V, f) schedule: the clock is shared by
all in-flight lanes.  This benchmark drains a mixed-length queue through the
length-bucketed ``ClassifierServer`` and compares modeled accelerator energy
at the SAME per-sentence target latency under four accountings:

  * ``replay_max_vf``     — per-sentence race-to-idle: every sentence runs its
    exit schedule at the maximum point (the only FEASIBLE per-sentence policy
    on shared hardware, and the paper's latency-unbounded baseline);
  * ``per_sentence_alg1`` — Alg. 1 replayed per sentence as if each owned the
    clock.  INFEASIBLE on the real hardware (one LDO/ADPLL, no switching
    cost) — the paper's single-stream accounting, reported for reference.
    NOT a lower bound: Alg. 1 line 1 charges layer 1 at the maximum point
    unconditionally, while the live arbiter budgets pre-prediction layers
    at conservative-full-depth rate — identical at a slack-free target but
    cheaper when the target has headroom, so the feasible shared clock can
    legitimately undercut it;
  * ``shared_clock``      — the ``BatchedDVFSArbiter``: ONE (V, f) decision
    per fused step (max over per-lane required frequencies), misprediction
    escalation, LDO/ADPLL switching stall charged on every point change;
  * ``shared_clock_online`` — same arbiter but with NO offline calibration
    pass: the controller's per-bin exit quantiles update online as sentences
    retire (cold start predicts full depth, then tightens).

At a slack-free target (``--target-mult 1.0``) the shared clock degenerates
to race-to-idle — any lane predicted full-depth pins the single LDO at the
maximum point, a hardware reality the per-sentence analysis hides.  With
deployment-style headroom (default 1.5x the full-model latency) the arbiter
recovers most of the per-sentence savings while staying feasible.

Per-bucket cycle models: each lane is budgeted (deadline, step duration AND
energy) at its OWN bucket's layer cost, and the max-V/f baseline is priced
the same way, so short buckets are no longer overcharged at the largest
bucket's rate.

Interleaved EDF scenario (``batched_dvfs_edf_interleave``): a deep
largest-bucket drain is mid-flight when tight-deadline short-bucket requests
arrive; the step()-clocked engine's EDF policy must retire EVERY short
request before the drain completes, meet every short deadline, and add ZERO
compiled traces vs the sequential drain.  Queue-delay percentiles
(arrival -> first compute, in fused steps) make starvation regressions
visible.

Oversubscribed admission-control storm (``admission_storm``): best-effort
traffic is mid-flight when a storm of tight-SLO explicit requests arrives at
well past sustainable rate.  WITHOUT admission control every SLO is accepted
and the later ones are missed (accepted-then-missed), while best-effort
queue delay balloons behind the storm.  WITH the ``AdmissionController`` in
front of ``submit()`` — feasibility quotes priced by the per-bucket cycle
model at the arbiter's max operating point, just-in-time lane-occupancy
bounds, bounded best-effort queue with oldest-drop shedding, and preemptive
lane checkpointing — the infeasible tail is REJECTED at submission (callers
get the minimum feasible deadline), ZERO accepted SLOs are missed,
best-effort still completes with bounded p95 queue delay, and preemption
bounds the first accepted contract's lane wait by one fused step.  CI gates:
``accepted_slo_misses=0``, ``rejected>0``, ``best_effort_completed>0``, and
the ``step_traces<=bucket_count`` pair still holding with preemption on
(checkpoint/restore reuses the buckets' compiled paths).

Self-speculative decode storm (``speculative_decode``): the same mixed
classifier+decoder storm, decoder drained twice — per-token EE decode
(``spec_window=1``, 1.0 tokens per fused step by construction) vs
speculative block decode (``spec_window=4`` + threshold schedule: off-ramp
drafts, remaining layers verify, lanes advance by accepted prefixes).  CI
gates: ``spec_parity=1`` (accepted tokens bit-identical to the per-token
baseline), ``tps_ratio>=1.5`` tokens/fused-step at ZERO accepted-SLO
misses on both runs, one compile per cache bucket, and a schema-valid
``speculative_decode`` entry in the BENCH_serving.json history.

Multi-task residency storm (``multitask_residency``): four compressed task
deployments share an SRAM working set that fits only two, over an eNVM
backing store; identical mixed-SLO round-robin traffic is drained under the
task-affinity-aware policy vs residency-blind EDF.  CI gates: affinity wins
on energy/request (swap energy included) at zero accepted-SLO misses on both
runs, affinity's ``task_swaps`` stays bounded by the task count, and the
``step_traces``/``bucket_count`` pair still holds (residency adds no traces).

Also regression-checks the bucketed engine's compile telemetry: the fused
step must trace EXACTLY once per length bucket across the whole drain — in
ALL scenarios (the CI grep-gate in scratch/run_ci.sh parses every
``step_traces``/``bucket_count`` pair emitted below, and a second gate
requires ``edf_deadline_misses=0``).

Usage:
  python benchmarks/bench_batched_dvfs.py            # trained toy EdgeBERT
  python benchmarks/bench_batched_dvfs.py --smoke    # untrained, CI-fast
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_bench_history, emit, git_tag, trained_albert
from benchmarks.harness.traffic import mixed_queue
from repro.configs.base import get_smoke_config
from repro.core.early_exit import ExitThresholdSchedule, OnlineExitCalibrator
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    calibrate_predictor,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, Request

LANES = 4


def _with_threshold(cfg, threshold: float):
    return cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=float(threshold)
        )
    )


def _setup(smoke: bool):
    if smoke:
        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3, seed=0)
    else:
        model, params, _, data, cfg = trained_albert()
    # spread exits across layers: threshold at the 30th pct of FIRST-off-ramp
    # entropies -> ~30% exit at layer 1, the rest deeper
    out = model.apply_train(params, {"tokens": jnp.asarray(data.batch(0)["tokens"])})
    thr = float(np.quantile(np.asarray(out.all_entropies[0]), 0.3))
    cfg = _with_threshold(cfg, thr)
    model = build_model(cfg)
    return model, params, cfg, data, thr


# queue shaping now lives in the shared harness package (every benchmark
# shapes storm traffic identically); the alias keeps call sites unchanged
_mixed_queue = mixed_queue


def _drain(model, params, buckets, reqs, arbiter) -> dict:
    server = ClassifierServer(
        model, params, batch_lanes=LANES, arbiter=arbiter, buckets=buckets
    )
    for r in reqs:
        server.submit(
            Request(uid=r.uid, tokens=r.tokens, max_new_tokens=r.max_new_tokens)
        )
    stats = server.run()
    stats["exits"] = [server.done[r.uid].exit_layer for r in reqs]
    stats["traces"] = {r.uid: server.done[r.uid].entropy_trace for r in reqs}
    stats["req_buckets"] = [server.done[r.uid].bucket for r in reqs]
    return stats


def _interleaved_edf(model, params, cfg, buckets, data, ctrl_factory) -> dict:
    """Deep largest-bucket drain + late tight-deadline short requests.

    Exercises the step()-clocked API end to end: the drain is advanced a few
    steps, the short requests are submitted MID-FLIGHT with a per-request
    SLO, and the EDF policy must preempt the drain to retire them — with no
    new compiled traces and no short-request deadline miss.
    """
    from repro.serving.dvfs import BatchedDVFSArbiter

    ctrl = ctrl_factory()
    arb = BatchedDVFSArbiter(ctrl)
    server = ClassifierServer(
        model, params, batch_lanes=LANES, arbiter=arb, buckets=buckets
    )
    deep_b, short_b = max(buckets), min(buckets)
    n_deep, n_short = 5 * LANES, LANES
    for i in range(n_deep):
        b = data.batch(300 + i // data.global_batch)
        toks = b["tokens"][i % data.global_batch][:deep_b]
        server.submit(Request(uid=i, tokens=np.asarray(toks, np.int32)))
    # advance until ~a quarter of the drain retired: genuinely mid-flight,
    # with well over the shorts' worth of deep work still queued behind them
    while len(server.done) < n_deep // 4:
        assert server.step() is not None, "drain exhausted during warmup"
    # tight-but-feasible SLO: full predicted depth at the SHORT bucket's own
    # layer cost, with modest headroom for arbitration and switching stalls
    t_short = ctrl.cycles_for_seq_len(short_b) / ctrl.max_op.freq_hz
    deadline = cfg.n_layers * t_short * 1.5
    for j in range(n_short):
        b = data.batch(400 + j // data.global_batch)
        toks = b["tokens"][j % data.global_batch][: short_b - 2]
        server.submit(Request(
            uid=1000 + j, tokens=np.asarray(toks, np.int32), deadline_s=deadline
        ))
    while server.step() is not None:
        pass
    st = server.telemetry()
    drain_last = max(server.done[i].retire_step for i in range(n_deep))
    shorts = [server.done[1000 + j] for j in range(n_short)]
    st["short_before_drain"] = sum(1 for r in shorts if r.retire_step < drain_last)
    st["n_short"] = n_short
    # the SLO is submission-anchored: modeled queue wait counts toward it
    st["edf_deadline_misses"] = sum(
        1
        for r in shorts
        if (r.admit_s - r.arrival_s) + (r.latency_s or 0.0)
        > r.deadline_s * (1 + 1e-9)
    )
    return st


def _admission_storm(model, params, cfg, buckets, data, ctrl_factory) -> dict:
    """Oversubscribed tight-SLO storm, with and without admission control.

    Best-effort work fills every lane first; then a storm of explicit
    requests arrives whose combined work is far beyond capacity at their
    shared relative SLO.  The no-admission baseline accepts all of them and
    misses the tail; the admission run must reject that tail at submission
    time instead, miss ZERO accepted SLOs, shed (bounded queue) rather than
    starve best-effort, and use preemption so the first contract's admission
    does not wait for a best-effort retire."""
    from repro.serving.admission import AdmissionController

    short_b = min(buckets)
    n_be, n_storm = 3 * LANES, 6 * LANES
    out = {}
    for admission in (True, False):
        ctrl = ctrl_factory()
        arb = BatchedDVFSArbiter(ctrl)
        server = ClassifierServer(
            model, params, batch_lanes=LANES, arbiter=arb, buckets=buckets,
            preempt=admission,
        )
        if admission:
            ac = AdmissionController(server, max_best_effort_queue=LANES)
            submit = ac.submit
        else:
            submit = server.submit
        # best-effort floor: mixed lengths across the buckets, lanes go busy
        be = _mixed_queue(data, buckets, n_be, seed=7)
        for r in be:
            submit(Request(uid=r.uid, tokens=r.tokens))
        for _ in range(2):                       # storm hits MID-FLIGHT
            assert server.step() is not None
        # the storm's shared SLO: ~2 contracts' worth of just-in-time lane
        # time per lane — feasible for the front of the storm, infeasible
        # once accepted contracts stack up
        t_short = ctrl.cycles_for_seq_len(short_b) / ctrl.max_op.freq_hz
        deadline = cfg.n_layers * t_short * 2.0 * 2
        for j in range(n_storm):
            b = data.batch(500 + j // data.global_batch)
            toks = b["tokens"][j % data.global_batch][: short_b - 2]
            submit(Request(
                uid=1000 + j, tokens=np.asarray(toks, np.int32),
                deadline_s=deadline,
            ))
        while server.step() is not None:
            pass
        st = server.telemetry()
        done = server.done
        accepted_slo = [r for r in done.values() if r.deadline_s is not None]
        st["accepted_explicit"] = len(accepted_slo)
        be_done = [r for r in done.values() if r.deadline_s is None]
        st["best_effort_completed"] = len(be_done)
        be_delays = [
            r.first_compute_step - r.arrival_step
            for r in be_done
            if r.first_compute_step is not None
        ]
        st["best_effort_p95_steps"] = (
            float(np.percentile(be_delays, 95)) if be_delays else 0.0
        )
        out["with_admission" if admission else "no_admission"] = st
    return out


def _decode_early_exit(model, params, cfg, data, stats, ctrl_factory) -> dict:
    """Mixed classifier+decoder storm on ONE shared arbiter: per-token exit
    on vs off.

    A classifier drain and an LM-decode drain share one LDO/ADPLL: the two
    servers interleave bucket steps on the arbiter's clock, decoder SLOs are
    explicit (priced conservatively to stay feasible in BOTH runs), and the
    decoder is run twice with identical traffic — per-token entropy exit
    ENABLED (off-ramp threshold probed to spread exits) vs full-depth
    decode.  Exit-enabled decode must spend strictly less modeled energy at
    EQUAL accepted-SLO misses (zero), with the fused EE decode still
    compiling exactly once per cache bucket.
    """
    import dataclasses as _dc

    from repro.serving.engine import DecoderServer, probe_exit_threshold

    dcfg = _dc.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none",
        n_layers=cfg.n_layers,
    )
    dmodel = build_model(dcfg)
    dparams = dmodel.init_params(jax.random.PRNGKey(11))
    rng = np.random.default_rng(11)
    n_dec, max_new, dbuckets = 2 * LANES, 5, (16,)
    prompts = [
        rng.integers(4, dcfg.vocab_size, size=int(rng.integers(4, 9))).astype(np.int32)
        for _ in range(n_dec)
    ]

    # the shared probe recipe: median observed first-off-ramp entropy, so
    # the exit-enabled run genuinely spreads exits across layers
    thr = probe_exit_threshold(
        dmodel, dparams, prompts, batch_lanes=LANES, buckets=dbuckets,
        max_new_tokens=max_new,
    )

    # classifier side of the storm: best-effort mixed lengths (same model
    # family as the main drains; its bucket set anchors the arbiter stats)
    cls_buckets = (16, 32) if data.seq_len <= 32 else (32, 64, data.seq_len)
    cls_reqs = _mixed_queue(data, cls_buckets, 2 * LANES, seed=11)

    # conservative decoder SLO: serialized classifier backlog at max op plus
    # the request's own cold full-depth quote, with headroom — identical in
    # both runs, so the miss comparison is apples to apples
    t_cls_full = no_early_exit_baseline(stats)["latency_s"]
    out = {}
    for label, t in (("exit", thr), ("full", None)):
        ctrl = ctrl_factory()
        arb = BatchedDVFSArbiter(ctrl)
        cls = ClassifierServer(
            model, params, batch_lanes=LANES, arbiter=arb, buckets=cls_buckets,
        )
        dec = DecoderServer(
            dmodel, dparams, batch_lanes=LANES, max_seq=32, eos_id=-1,
            buckets=dbuckets, arbiter=arb, exit_threshold=t,
        )
        own_quote = arb.min_latency_quote(float(max_new), dec._cycles_for(16))
        deadline = (len(cls_reqs) * t_cls_full + own_quote) * 2.0
        for r in cls_reqs:
            cls.submit(Request(uid=r.uid, tokens=r.tokens))
        for i, p in enumerate(prompts):
            dec.submit(Request(
                uid=1000 + i, tokens=p, max_new_tokens=max_new,
                deadline_s=deadline,
            ))
        while not (cls.sched.idle and dec.sched.idle):
            cls.step()
            dec.step()
        st = dec.telemetry()
        st["cls_step_traces"] = cls.telemetry()["step_traces"]
        out[label] = st
    return out


def _speculative_decode(model, params, cfg, data, stats, ctrl_factory) -> dict:
    """Self-speculative decode via the off-ramps vs per-token EE decode,
    under the same mixed classifier+decoder storm on ONE shared arbiter.

    The decoder drains IDENTICAL traffic twice: ``spec_window=1`` (the
    per-token early-exit baseline — exactly one accepted token per fused
    step, so its ``tokens_per_fused_step`` is 1.0 by construction) vs
    ``spec_window=4`` with a threshold schedule (the off-ramp drafts a
    block, the remaining layers verify, lanes advance by their accepted
    prefix).  Because every speculative slot IS one ``decode_step_ee``
    evaluation, accepted tokens are bit-identical to the baseline — the
    scenario gates on that parity (``spec_parity=1``), on throughput
    (``tokens_per_fused_step`` >= 1.5x the per-token baseline) at ZERO
    accepted-SLO misses on both runs, and on the fused speculative step
    still compiling exactly once per cache bucket.
    """
    import dataclasses as _dc

    from repro.serving.engine import DecoderServer, probe_exit_threshold

    dcfg = _dc.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none",
        n_layers=cfg.n_layers,
    )
    dmodel = build_model(dcfg)
    dparams = dmodel.init_params(jax.random.PRNGKey(13))
    rng = np.random.default_rng(13)
    n_dec, max_new, dbuckets, spec_w = 2 * LANES, 5, (16,), 4
    prompts = [
        rng.integers(4, dcfg.vocab_size, size=int(rng.integers(4, 9))).astype(np.int32)
        for _ in range(n_dec)
    ]

    # loose-ish probe (80th pct of first-off-ramp entropies): most draft
    # slots agree with the verifier, so speculative blocks genuinely run
    # deep and the throughput contrast is structural, not luck
    thr = probe_exit_threshold(
        dmodel, dparams, prompts, batch_lanes=LANES, buckets=dbuckets,
        max_new_tokens=max_new, quantile=0.8,
    )

    cls_buckets = (16, 32) if data.seq_len <= 32 else (32, 64, data.seq_len)
    cls_reqs = _mixed_queue(data, cls_buckets, 2 * LANES, seed=13)
    t_cls_full = no_early_exit_baseline(stats)["latency_s"]
    out = {}
    for label, w in (("spec", spec_w), ("base", 1)):
        ctrl = ctrl_factory()
        arb = BatchedDVFSArbiter(ctrl)
        cls = ClassifierServer(
            model, params, batch_lanes=LANES, arbiter=arb, buckets=cls_buckets,
        )
        dec = DecoderServer(
            dmodel, dparams, batch_lanes=LANES, max_seq=32, eos_id=-1,
            buckets=dbuckets, arbiter=arb, exit_threshold=thr, spec_window=w,
            threshold_schedule=ExitThresholdSchedule(thr) if w > 1 else None,
        )
        own_quote = arb.min_latency_quote(float(max_new), dec._cycles_for(16))
        deadline = (len(cls_reqs) * t_cls_full + own_quote) * 2.0
        for r in cls_reqs:
            cls.submit(Request(uid=r.uid, tokens=r.tokens))
        for i, p in enumerate(prompts):
            dec.submit(Request(
                uid=1000 + i, tokens=p, max_new_tokens=max_new,
                deadline_s=deadline,
            ))
        while not (cls.sched.idle and dec.sched.idle):
            cls.step()
            dec.step()
        st = dec.telemetry()
        st["cls_step_traces"] = cls.telemetry()["step_traces"]
        st["generated"] = {
            1000 + i: list(dec.done[1000 + i].generated) for i in range(n_dec)
        }
        out[label] = st
    sp, ba = out["spec"], out["base"]
    out["spec_parity"] = int(sp["generated"] == ba["generated"])
    out["tps_ratio"] = (
        sp["tokens_per_fused_step"] / ba["tokens_per_fused_step"]
        if ba["tokens_per_fused_step"] else 0.0
    )
    return out


def _multitask_residency(model, params, cfg, data, ctrl_factory) -> dict:
    """N tasks > SRAM working set under a mixed-SLO round-robin storm:
    task-affinity-aware stepping vs residency-blind EDF on one shared clock.

    Four compressed task deployments (movement-pruned + span-budgeted,
    bitmask-encoded in eNVM) share an SRAM working set that fits only TWO of
    them.  Both runs drain IDENTICAL traffic — two explicit-SLO classes
    (tight-ish and loose), submitted round-robin across the tasks with a
    strictly rotating deadline order — through a ``ResidencyRouter`` whose
    per-task servers share one DVFS arbiter.  Residency-blind EDF chases the
    globally earliest deadline across tasks whose weights do not co-fit, so
    nearly every task revisit is an eNVM swap (stall on the shared clock +
    swap energy); the affinity policy batches each task through the warm
    working set while slack permits and swaps each task in ONCE.  The gate:
    affinity wins on energy/request (swap energy included) at zero
    accepted-SLO misses on BOTH runs, with affinity's ``task_swaps`` bounded
    by the task count and no extra jit traces from residency."""
    from repro.serving.residency import (
        BlindEDFTaskPolicy,
        ResidencyRouter,
        TaskAffinityPolicy,
        TaskDeployment,
        TaskResidencyManager,
    )

    tasks = ("mnli", "qqp", "sst2", "qnli")
    rbuckets = (16,)
    n_per_task = 2 * LANES                    # two lane-refill waves per task
    total = len(tasks) * n_per_task
    out = {}
    for label, policy in (
        ("affinity", TaskAffinityPolicy()),
        ("blind_edf", BlindEDFTaskPolicy()),
    ):
        ctrl = ctrl_factory()
        deps = {
            t: TaskDeployment(
                t, n_params=11e6, pruning_occupancy=0.4,
                spans=(0,) * 6 + (64,) * 6,
            )
            for t in tasks
        }
        res = TaskResidencyManager(
            deps, sram_bytes=2.0 * deps["mnli"].storage()["total_bytes"]
        )
        router = ResidencyRouter(
            model, params["embed"], {t: params for t in tasks},
            residency=res, deployments=deps, task_policy=policy,
            arbiter=BatchedDVFSArbiter(ctrl), buckets=rbuckets,
            batch_lanes=LANES,
        )
        t_step = ctrl.cycles_for_seq_len(rbuckets[0]) / ctrl.max_op.freq_hz
        stall = deps["mnli"].swap_cost()["latency_s"]
        # generous enough that BOTH policies meet every contract (blind pays
        # every swap stall out of this budget), tight enough to rank
        base = total * cfg.n_layers * t_step * 3.0 + 2 * total * stall
        for i in range(total):
            t = tasks[i % len(tasks)]
            b = data.batch(600 + i // data.global_batch)
            toks = np.asarray(
                b["tokens"][i % data.global_batch][: rbuckets[0] - 2], np.int32
            )
            # two SLO classes by wave, rotating strictly in submission order:
            # the globally most-urgent contract alternates TASKS, the worst
            # case for residency-blind EDF
            wave = i // len(tasks)
            deadline = base * (1.0 + (wave % 2)) + i * t_step
            router.submit(t, Request(uid=i, tokens=toks, deadline_s=deadline))
        router.run_all()
        tel = router.telemetry()
        tel["energy_per_req_j"] = tel["energy_j"] / total
        tel["max_step_traces"] = max(
            srv.telemetry()["step_traces"] for srv in router.tasks.values()
        )
        out[label] = tel
    aff, bl = out["affinity"], out["blind_edf"]
    out["affinity_beats_blind"] = int(
        aff["energy_per_req_j"] < bl["energy_per_req_j"]
    )
    out["swaps_bounded"] = int(aff["task_swaps"] <= len(tasks))
    out["n_tasks"] = len(tasks)
    out["total"] = total
    out["bucket_count"] = len(rbuckets)
    return out


def _pallas_serving_bench(model, params, cfg, data, buckets, ctrl_factory) -> dict:
    """Ref vs Pallas fused serving step: parity gates + wall-clock timing.

    The SAME mixed queue (half best-effort, half explicit contracts admitted
    at their own feasibility quote) drains through two otherwise-identical
    servers, ``use_pallas=False`` and ``True``.  The first drain compiles and
    gates parity (logits fp-tolerance, exit depths exact, zero accepted-SLO
    misses); a second identical drain on the now-warm server times each
    fused ``step()`` with ``time.perf_counter`` for p50/p95 wall clock and
    must add ZERO new traces.  On CPU the kernels run in interpret mode —
    Python-rate, so the speedup column is diagnostic there and only becomes
    a gate on a real TPU backend.
    """
    import time as _time

    from repro.serving.admission import AdmissionController

    n = 3 * LANES
    reqs = _mixed_queue(data, buckets, n, seed=23)
    out = {}
    for label, use_pallas in (("ref", False), ("pallas", True)):
        arb = BatchedDVFSArbiter(ctrl_factory())
        srv = ClassifierServer(
            model, params, batch_lanes=LANES, arbiter=arb, buckets=buckets,
            use_pallas=use_pallas,
        )
        ac = AdmissionController(srv)
        for i, r in enumerate(reqs):
            if i % 2:
                q = ac.quote(Request(uid=r.uid, tokens=r.tokens, deadline_s=1e9))
                d = ac.submit(Request(
                    uid=r.uid, tokens=r.tokens, deadline_s=q.min_deadline_s
                ))
                assert d.admitted, r.uid
            else:
                srv.submit(Request(uid=r.uid, tokens=r.tokens))
        srv.run()                              # compile + parity drain
        traces_cold = srv.telemetry()["step_traces"]
        for r in reqs:                         # identical warm traffic, timed
            srv.submit(Request(uid=10_000 + r.uid, tokens=r.tokens))
        wall = []
        while True:
            t0 = _time.perf_counter()
            if srv.step() is None:
                break
            wall.append(_time.perf_counter() - t0)
        st = srv.telemetry()
        st["warm_added_traces"] = st["step_traces"] - traces_cold
        st["wall_p50_ms"] = float(np.percentile(wall, 50) * 1e3)
        st["wall_p95_ms"] = float(np.percentile(wall, 95) * 1e3)
        st["energy_per_req_j"] = st["arb_energy_j"] / (2 * n)
        st["slo_miss_rate"] = (
            st["accepted_slo_misses"] / st["accepted"] if st["accepted"] else 0.0
        )
        st["exits"] = [srv.done[r.uid].exit_layer for r in reqs]
        st["logits"] = np.stack(
            [np.asarray(srv.done[r.uid].result) for r in reqs]
        )
        out[label] = st
    ref, pal = out["ref"], out["pallas"]
    out["max_abs_logit_diff"] = float(
        np.max(np.abs(ref["logits"] - pal["logits"]))
    )
    out["logit_parity"] = bool(out["max_abs_logit_diff"] <= 2e-4)
    out["exit_parity"] = bool(ref["exits"] == pal["exits"])
    out["speedup"] = ref["wall_p50_ms"] / pal["wall_p50_ms"]
    return out


def _write_bench_spec_decode(path: str, sd: dict) -> None:
    """Append the speculative-decode scenario to the BENCH_serving.json
    history (same bounded v2 format as ``_write_bench_serving``), so CI can
    schema-check the throughput/parity gates from the artifact as well as
    from the emitted telemetry row."""
    sp, ba = sd["spec"], sd["base"]
    append_bench_history(path, {
        "scenario": "speculative_decode",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "tag": git_tag(),
        "spec_window": sp["spec_window"],
        "tokens_per_fused_step": sp["tokens_per_fused_step"],
        "baseline_tokens_per_step": ba["tokens_per_fused_step"],
        "tokens_per_step_ratio": sd["tps_ratio"],
        "avg_accepted_block": sp["avg_accepted_block"],
        "spec_parity": bool(sd["spec_parity"]),
        "accepted_slo_misses": (
            sp["accepted_slo_misses"] + ba["accepted_slo_misses"]
        ),
        "energy_per_token_j": sp["energy_j"] / sp["tokens"],
        "baseline_energy_per_token_j": ba["energy_j"] / ba["tokens"],
        "step_traces": sp["step_traces"],
        "bucket_count": 1,
    })


def _write_bench_serving(path: str, pal: dict, buckets, target_mult: float) -> None:
    """Append this run to the versioned BENCH_serving.json history.

    Each run is ONE entry (scenario ``pallas_serving``) in a bounded
    ``{"version": 2, "history": [...]}`` list — newest last, stamped with the
    backend, device count and a git-describable tag — so CI diffs the newest
    entry against the previous comparable one instead of only shape-checking
    an overwritten snapshot.  A pre-existing flat v1 file is migrated as the
    history's first entry."""

    def scenario(st):
        return {
            "step_wall_p50_ms": st["wall_p50_ms"],
            "step_wall_p95_ms": st["wall_p95_ms"],
            "energy_per_request_j": st["energy_per_req_j"],
            "accepted": st["accepted"],
            "accepted_slo_misses": st["accepted_slo_misses"],
            "accepted_slo_miss_rate": st["slo_miss_rate"],
            "step_traces": st["step_traces"],
            "warm_added_traces": st["warm_added_traces"],
        }

    entry = {
        "scenario": "pallas_serving",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "tag": git_tag(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "target_mult": target_mult,
        "bucket_count": len(buckets),
        "ref": scenario(pal["ref"]),
        "pallas": scenario(pal["pallas"]),
        "speedup_ref_over_pallas_p50": pal["speedup"],
        "max_abs_logit_diff": pal["max_abs_logit_diff"],
        "logit_parity": pal["logit_parity"],
        "exit_depth_parity": pal["exit_parity"],
    }
    append_bench_history(path, entry)


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--smoke", action="store_true", help="untrained weights, CI-fast")
    parser.add_argument("--queue", type=int, default=None, help="sentences to drain")
    parser.add_argument(
        "--target-mult", type=float, default=1.5,
        help="per-sentence latency target as a multiple of the full-model "
             "latency (1.0 = slack-free: the shared clock degenerates to "
             "race-to-idle)",
    )
    args, _ = parser.parse_known_args()  # tolerate the suite runner's argv

    model, params, cfg, data, thr = _setup(args.smoke)
    n_queue = args.queue if args.queue is not None else (24 if args.smoke else 48)
    assert n_queue > 0, "--queue must be positive"
    buckets = (16, 32) if data.seq_len <= 32 else (32, 64, data.seq_len)

    # controller stats anchor at the LARGEST bucket; the arbiter then budgets
    # every lane at its OWN bucket's layer cycles (per-bucket cycle models),
    # so short buckets are no longer overcharged at the worst-case rate
    stats = albert_layer_stats(seq_len=max(buckets))
    stats.n_layers = cfg.n_layers
    target = no_early_exit_baseline(stats)["latency_s"] * args.target_mult

    predictor = calibrate_predictor(
        model,
        params,
        [data.batch(100 + i) for i in range(2 if args.smoke else 6)],
        quantile=1.0,
    )
    reqs = _mixed_queue(data, buckets, n_queue)

    # ---- shared clock, offline-calibrated LUT --------------------------------
    ctrl = LatencyAwareDVFSController(stats, target, predictor=predictor)
    arb = BatchedDVFSArbiter(ctrl)
    st = _drain(model, params, buckets, reqs, arb)
    e_shared = st["arb_energy_j"]
    misses = st["deadline_misses"]

    # ---- shared clock, ONLINE calibration (no offline profiling pass) -------
    ctrl_on = LatencyAwareDVFSController(
        stats, target,
        online_calibrator=OnlineExitCalibrator(
            cfg.n_layers, hi=float(np.log(cfg.edgebert.early_exit.num_classes)) + 0.1
        ),
    )
    st_on = _drain(model, params, buckets, reqs, BatchedDVFSArbiter(ctrl_on))
    e_online = st_on["arb_energy_j"]

    # ---- per-sentence accountings over the SAME drain ------------------------
    # max-V/f replay priced at each sentence's OWN bucket cost, matching the
    # arbiter's per-bucket cycle models (a fair baseline: pricing it at the
    # largest bucket would hand the shared clock a free win on short buckets)
    exits = st["exits"]
    e_max_vf = float(
        sum(
            exits[i]
            * ctrl.layer_energy(ctrl.max_op)
            * ctrl.cycles_for_seq_len(st["req_buckets"][i])
            / ctrl.cycles_per_layer
            for i in range(n_queue)
        )
    )
    e_alg1 = float(
        sum(
            ctrl.sentence_report(st["traces"][i], exit_layer=exits[i]).energy_j
            for i in range(n_queue)
        )
    )

    emit(
        "batched_dvfs_replay_max_vf", 0.0,
        f"energy_j={e_max_vf:.4e};target_s={target:.4e};queue={n_queue}",
    )
    emit(
        "batched_dvfs_per_sentence_alg1", 0.0,
        f"energy_j={e_alg1:.4e};vs_max_vf={e_max_vf / e_alg1:.2f}x;feasible=no",
    )
    emit(
        "batched_dvfs_shared_clock", 0.0,
        f"energy_j={e_shared:.4e};vs_max_vf={e_max_vf / e_shared:.2f}x;"
        f"op_switches={st['op_switches']};switch_energy_j={st['switch_energy_j']:.2e};"
        f"deadline_misses={misses};avg_exit={np.mean(exits):.2f}/{cfg.n_layers}",
    )
    emit(
        "batched_dvfs_shared_clock_online", 0.0,
        f"energy_j={e_online:.4e};vs_max_vf={e_max_vf / e_online:.2f}x;"
        f"deadline_misses={st_on['deadline_misses']};calibration=online",
    )
    emit(
        "batched_engine_compiles", 0.0,
        f"step_traces={st['step_traces']};bucket_count={len(buckets)};"
        f"per_bucket={st['step_traces_per_bucket']};lane_occupancy={st['lane_occupancy']:.2f}",
    )
    emit(
        "batched_queue_delay", 0.0,
        f"p50_steps={st['queue_delay_steps_p50']:.1f};"
        f"p95_steps={st['queue_delay_steps_p95']:.1f};"
        f"p99_steps={st['queue_delay_steps_p99']:.1f};"
        f"max_steps={st['queue_delay_steps_max']:.0f};queue={n_queue};lanes={LANES}",
    )

    # ---- interleaved EDF scenario: late tight-SLO shorts vs a deep drain -----
    st_edf = _interleaved_edf(
        model, params, cfg, buckets, data,
        lambda: LatencyAwareDVFSController(stats, target, predictor=predictor),
    )
    emit(
        "batched_dvfs_edf_interleave", 0.0,
        f"short_before_drain={st_edf['short_before_drain']}/{st_edf['n_short']};"
        f"edf_deadline_misses={st_edf['edf_deadline_misses']};"
        f"step_traces={st_edf['step_traces']};bucket_count={len(buckets)};"
        f"queue_delay_p95={st_edf['queue_delay_steps_p95']:.1f}",
    )

    # ---- oversubscribed tight-SLO storm: admission control vs accept-all -----
    storm = _admission_storm(
        model, params, cfg, buckets, data,
        lambda: LatencyAwareDVFSController(stats, target, predictor=predictor),
    )
    ad, na = storm["with_admission"], storm["no_admission"]
    emit(
        "admission_storm", 0.0,
        f"accepted_slo_misses={ad['accepted_slo_misses']};"
        f"rejected={ad['rejected']};requoted={ad['requoted']};shed={ad['shed']};"
        f"preemptions={ad['preemptions']};"
        f"restored_steps_saved={ad['restored_steps_saved']};"
        f"accepted_explicit={ad['accepted_explicit']};"
        f"best_effort_completed={ad['best_effort_completed']};"
        f"best_effort_p95={ad['best_effort_p95_steps']:.1f};"
        f"step_traces={ad['step_traces']};bucket_count={len(buckets)}",
    )
    emit(
        "admission_storm_baseline", 0.0,
        f"noadmission_slo_misses={na['accepted_slo_misses']};"
        f"accepted_explicit={na['accepted_explicit']};"
        f"best_effort_p95={na['best_effort_p95_steps']:.1f};rejected=0",
    )

    # ---- mixed classifier+decoder storm: per-token decode exit on vs off ----
    dee = _decode_early_exit(
        model, params, cfg, data, stats,
        lambda: LatencyAwareDVFSController(stats, target, predictor=predictor),
    )
    de, df = dee["exit"], dee["full"]
    emit(
        "decode_early_exit", 0.0,
        f"exit_energy_j={de['energy_j']:.4e};full_energy_j={df['energy_j']:.4e};"
        f"exit_beats_full={int(de['energy_j'] < df['energy_j'])};"
        f"accepted_slo_misses={de['accepted_slo_misses']};"
        f"full_accepted_slo_misses={df['accepted_slo_misses']};"
        f"avg_token_exit={de['avg_token_exit_layer']:.2f}/{cfg.n_layers};"
        f"decode_savings={de['decode_runtime_savings']:.0%};"
        f"step_traces={de['step_traces']};bucket_count=1;"
        f"cls_step_traces={de['cls_step_traces']}",
    )

    # ---- self-speculative decode via the off-ramps: block vs per-token ------
    sd = _speculative_decode(
        model, params, cfg, data, stats,
        lambda: LatencyAwareDVFSController(stats, target, predictor=predictor),
    )
    sp, ba = sd["spec"], sd["base"]
    emit(
        "speculative_decode", 0.0,
        f"spec_tokens_per_step={sp['tokens_per_fused_step']:.2f};"
        f"base_tokens_per_step={ba['tokens_per_fused_step']:.2f};"
        f"tps_ratio={sd['tps_ratio']:.2f};spec_parity={sd['spec_parity']};"
        f"avg_accepted_block={sp['avg_accepted_block']:.2f};"
        f"spec_window={sp['spec_window']};"
        f"accepted_slo_misses={sp['accepted_slo_misses'] + ba['accepted_slo_misses']};"
        f"spec_energy_j={sp['energy_j']:.4e};base_energy_j={ba['energy_j']:.4e};"
        f"step_traces={sp['step_traces']};bucket_count=1;"
        f"cls_step_traces={sd['spec']['cls_step_traces']}",
    )

    # ---- ref vs Pallas fused serving step: parity + wall clock ---------------
    pal = _pallas_serving_bench(
        model, params, cfg, data, buckets,
        lambda: LatencyAwareDVFSController(stats, target, predictor=predictor),
    )
    pr, pp = pal["ref"], pal["pallas"]
    emit(
        "pallas_serving_step", 0.0,
        f"ref_p50_ms={pr['wall_p50_ms']:.2f};ref_p95_ms={pr['wall_p95_ms']:.2f};"
        f"pallas_p50_ms={pp['wall_p50_ms']:.2f};pallas_p95_ms={pp['wall_p95_ms']:.2f};"
        f"speedup={pal['speedup']:.2f}x;parity={int(pal['logit_parity'])};"
        f"exit_parity={int(pal['exit_parity'])};"
        f"max_abs_logit_diff={pal['max_abs_logit_diff']:.1e};"
        f"pallas_slo_misses={pp['accepted_slo_misses']};"
        f"energy_per_req_j={pp['energy_per_req_j']:.3e};"
        f"step_traces={pp['step_traces']};bucket_count={len(buckets)}",
    )
    bench_json = os.path.join(_ROOT, "BENCH_serving.json")
    _write_bench_serving(bench_json, pal, buckets, args.target_mult)
    _write_bench_spec_decode(bench_json, sd)
    print(f"wrote {os.path.normpath(bench_json)}", flush=True)

    # ---- multi-task residency: affinity-aware vs residency-blind EDF ---------
    mtr = _multitask_residency(
        model, params, cfg, data,
        lambda: LatencyAwareDVFSController(stats, target, predictor=predictor),
    )
    mta, mtb = mtr["affinity"], mtr["blind_edf"]
    emit(
        "multitask_residency", 0.0,
        f"affinity_energy_per_req_j={mta['energy_per_req_j']:.4e};"
        f"blind_energy_per_req_j={mtb['energy_per_req_j']:.4e};"
        f"affinity_beats_blind={mtr['affinity_beats_blind']};"
        f"accepted_slo_misses={mta['accepted_slo_misses'] + mtb['accepted_slo_misses']};"
        f"affinity_task_swaps={mta['task_swaps']};"
        f"blind_task_swaps={mtb['task_swaps']};"
        f"swaps_bounded={mtr['swaps_bounded']};n_tasks={mtr['n_tasks']};"
        f"affinity_swap_stall_s={mta['swap_stall_s']:.3e};"
        f"blind_swap_stall_s={mtb['swap_stall_s']:.3e};"
        f"step_traces={mta['max_step_traces']};bucket_count={mtr['bucket_count']}",
    )

    ok = True
    if e_shared >= e_max_vf:
        print(
            f"FAIL: shared-clock energy {e_shared:.3e} !< per-sentence "
            f"max-V/f replay {e_max_vf:.3e} at equal target latency"
        )
        ok = False
    if st["step_traces"] > len(buckets):
        print(
            f"FAIL: fused step traced {st['step_traces']}x for "
            f"{len(buckets)} buckets (want exactly one compile per bucket)"
        )
        ok = False
    if st_edf["short_before_drain"] < st_edf["n_short"]:
        print(
            f"FAIL: EDF retired only {st_edf['short_before_drain']}/"
            f"{st_edf['n_short']} tight-deadline shorts before the deep "
            "drain completed (cross-bucket preemption broken)"
        )
        ok = False
    if st_edf["edf_deadline_misses"]:
        print(
            f"FAIL: {st_edf['edf_deadline_misses']}/{st_edf['n_short']} "
            "tight-deadline shorts missed their per-request SLO under EDF"
        )
        ok = False
    if st_edf["step_traces"] > len(buckets):
        print(
            f"FAIL: interleaved stepping retraced the fused step "
            f"({st_edf['step_traces']}x for {len(buckets)} buckets)"
        )
        ok = False
    if ad["accepted_slo_misses"]:
        print(
            f"FAIL: admission control accepted {ad['accepted_explicit']} "
            f"SLOs and missed {ad['accepted_slo_misses']} of them (the "
            "feasibility quote must be conservative)"
        )
        ok = False
    if not ad["rejected"]:
        print(
            "FAIL: the oversubscribed storm was fully accepted — admission "
            "control rejected nothing"
        )
        ok = False
    if not ad["best_effort_completed"]:
        print("FAIL: best-effort traffic starved to zero under admission")
        ok = False
    if not ad["preemptions"]:
        print(
            "FAIL: no lane was preempted — the storm should have evicted "
            "busy best-effort lanes for tighter-SLO contracts"
        )
        ok = False
    if not na["accepted_slo_misses"]:
        print(
            "WARN: the no-admission baseline missed nothing — the storm is "
            "not oversubscribed enough to demonstrate the contrast"
        )
    if ad["step_traces"] > len(buckets):
        print(
            f"FAIL: preemption/restore retraced the fused step "
            f"({ad['step_traces']}x for {len(buckets)} buckets)"
        )
        ok = False
    if de["energy_j"] >= df["energy_j"]:
        print(
            f"FAIL: exit-enabled decode energy {de['energy_j']:.3e} !< "
            f"full-depth decode {df['energy_j']:.3e} under the mixed storm"
        )
        ok = False
    if de["accepted_slo_misses"] or df["accepted_slo_misses"]:
        print(
            f"FAIL: decode storm missed accepted SLOs (exit="
            f"{de['accepted_slo_misses']}, full={df['accepted_slo_misses']}) "
            "— the energy comparison must hold at zero misses on both sides"
        )
        ok = False
    if de["step_traces"] > 1:
        print(
            f"FAIL: early-exit decode retraced the fused step "
            f"({de['step_traces']}x for 1 cache bucket)"
        )
        ok = False
    if not sd["spec_parity"]:
        print(
            "FAIL: speculative decode emitted different tokens than the "
            "per-token EE baseline — accepted tokens must be bit-identical "
            "by construction"
        )
        ok = False
    if sd["tps_ratio"] < 1.5:
        print(
            f"FAIL: speculative decode reached only "
            f"{sp['tokens_per_fused_step']:.2f} tokens/fused-step vs the "
            f"per-token baseline's {ba['tokens_per_fused_step']:.2f} "
            f"({sd['tps_ratio']:.2f}x, want >= 1.5x)"
        )
        ok = False
    if sp["accepted_slo_misses"] or ba["accepted_slo_misses"]:
        print(
            f"FAIL: speculative storm missed accepted SLOs (spec="
            f"{sp['accepted_slo_misses']}, base={ba['accepted_slo_misses']}) "
            "— the throughput win must hold at zero misses on both sides"
        )
        ok = False
    if sp["step_traces"] > 1 or ba["step_traces"] > 1:
        print(
            f"FAIL: speculative decode retraced the fused step (spec="
            f"{sp['step_traces']}x, base={ba['step_traces']}x for 1 cache "
            "bucket) — the block shape is fixed and masked, so threshold "
            "values and accept depths must not recompile"
        )
        ok = False
    if not pal["logit_parity"] or not pal["exit_parity"]:
        print(
            f"FAIL: Pallas serving step diverged from ref (max logit diff "
            f"{pal['max_abs_logit_diff']:.2e}, exit parity "
            f"{pal['exit_parity']}) — the dispatch layer must be "
            "numerically interchangeable"
        )
        ok = False
    for lbl, s in (("ref", pr), ("pallas", pp)):
        if s["accepted_slo_misses"]:
            print(
                f"FAIL: {lbl} serving drain missed {s['accepted_slo_misses']} "
                "accepted SLOs (quotes must stay conservative under Pallas)"
            )
            ok = False
        if s["warm_added_traces"]:
            print(
                f"FAIL: {lbl} warm timed drain added {s['warm_added_traces']} "
                "step traces (the timed pass must reuse every compile)"
            )
            ok = False
    if pp["step_traces"] != pr["step_traces"]:
        print(
            f"FAIL: Pallas routing changed the compile count "
            f"({pp['step_traces']} vs ref {pr['step_traces']}) — the flag is "
            "static and must add zero traces"
        )
        ok = False
    if not mtr["affinity_beats_blind"]:
        print(
            f"FAIL: affinity-aware scheduling energy/request "
            f"{mta['energy_per_req_j']:.3e} !< residency-blind EDF "
            f"{mtb['energy_per_req_j']:.3e} under the multi-task storm"
        )
        ok = False
    if mta["accepted_slo_misses"] or mtb["accepted_slo_misses"]:
        print(
            f"FAIL: multitask residency storm missed accepted SLOs "
            f"(affinity={mta['accepted_slo_misses']}, "
            f"blind={mtb['accepted_slo_misses']}) — the energy win must hold "
            "at zero misses on both sides"
        )
        ok = False
    if not mtr["swaps_bounded"]:
        print(
            f"FAIL: affinity-aware stepping swapped {mta['task_swaps']} times "
            f"for {mtr['n_tasks']} tasks (each task should swap in once)"
        )
        ok = False
    if mta["max_step_traces"] > mtr["bucket_count"]:
        print(
            f"FAIL: residency stepping retraced the fused step "
            f"({mta['max_step_traces']}x for {mtr['bucket_count']} bucket(s))"
        )
        ok = False
    # NOTE: no speedup gate — on CPU the kernels run in interpret mode
    # (Python-rate); ref-vs-pallas wall clock is a trend metric there and
    # only meaningful as a gate on a TPU backend.
    for name, s in (("shared_clock", st), ("online", st_on)):
        if s["deadline_misses"]:
            print(
                f"WARN: {name}: {s['deadline_misses']}/{n_queue} sentences "
                "overshot the target (entropy outside the calibration range)"
            )
    if not ok:
        sys.exit(1)
    print(
        f"OK: shared-clock arbitration {e_max_vf / e_shared:.2f}x below "
        f"max-V/f replay (single-stream Alg. 1 accounting: "
        f"{e_max_vf / e_alg1:.2f}x, infeasible on shared hardware) at target "
        f"{target * 1e3:.2f} ms; one compile per bucket "
        f"({st['step_traces']}/{len(buckets)}); online calibration "
        f"{e_max_vf / e_online:.2f}x with no profiling pass; EDF interleave: "
        f"{st_edf['short_before_drain']}/{st_edf['n_short']} shorts beat the "
        f"drain, {st_edf['edf_deadline_misses']} SLO misses; admission storm: "
        f"{ad['accepted_explicit']} accepted / {ad['rejected']} rejected / "
        f"0 accepted-SLO misses (baseline missed {na['accepted_slo_misses']}), "
        f"{ad['preemptions']} preemptions saved {ad['restored_steps_saved']} "
        f"layers, best-effort p95 {ad['best_effort_p95_steps']:.0f} vs "
        f"{na['best_effort_p95_steps']:.0f} steps; decode early exit: "
        f"{df['energy_j'] / de['energy_j']:.2f}x below full depth at avg "
        f"token exit {de['avg_token_exit_layer']:.1f}/{cfg.n_layers}, 0 SLO "
        f"misses both sides; speculative decode: "
        f"{sp['tokens_per_fused_step']:.2f} tokens/fused-step "
        f"({sd['tps_ratio']:.2f}x the per-token baseline) at bit-exact "
        f"parity and 0 misses; multitask residency: affinity "
        f"{mta['task_swaps']} swaps vs blind EDF {mtb['task_swaps']}, "
        f"{mtb['energy_per_req_j'] / mta['energy_per_req_j']:.2f}x "
        "energy/request win at 0 misses"
    )


if __name__ == "__main__":
    main()
